//! Entry registry: every executable entry point's input specification
//! plus each model variant's canonical parameter list.
//!
//! Two constructors:
//! - [`Registry::native`] synthesizes the specs directly from the rust
//!   [`crate::config`] constants, mirroring `python/compile/aot.py`'s
//!   `build_entries` — no artifacts directory needed. This is what the
//!   default native backend runs against.
//! - [`Registry::load`] parses `artifacts/meta.json` (written by aot.py)
//!   and cross-checks it against the same constants, so the two sides
//!   cannot drift silently. The XLA backend requires this path.
//!
//! Because both backends validate through the same [`EntrySpec`], a
//! shape/dtype/arity mistake produces the identical error no matter
//! which backend executes the entry (see tests/backend_parity.rs).

use crate::config;
use crate::jsonx::Json;
use crate::runtime::Value;
use anyhow::{anyhow, bail, Result};
use std::collections::HashMap;
use std::path::Path;

#[derive(Clone, Debug, PartialEq)]
pub struct ArgSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

#[derive(Clone, Debug)]
pub struct EntrySpec {
    pub inputs: Vec<ArgSpec>,
}

impl EntrySpec {
    pub fn validate(&self, inputs: &[Value]) -> Result<()> {
        if inputs.len() != self.inputs.len() {
            bail!(
                "arity mismatch: got {} inputs, spec has {} ({})",
                inputs.len(),
                self.inputs.len(),
                self.inputs
                    .iter()
                    .map(|a| a.name.as_str())
                    .collect::<Vec<_>>()
                    .join(", ")
            );
        }
        for (v, spec) in inputs.iter().zip(&self.inputs) {
            if v.shape() != spec.shape.as_slice() {
                bail!(
                    "arg `{}`: shape {:?} != expected {:?}",
                    spec.name,
                    v.shape(),
                    spec.shape
                );
            }
            if v.dtype() != spec.dtype {
                bail!(
                    "arg `{}`: dtype {} != expected {}",
                    spec.name,
                    v.dtype(),
                    spec.dtype
                );
            }
        }
        Ok(())
    }
}

/// One model variant's canonical parameter list (name -> shape, ordered).
#[derive(Clone, Debug)]
pub struct VariantMeta {
    pub name: String,
    pub moe_signature: String,
    pub params: Vec<(String, Vec<usize>)>,
}

impl VariantMeta {
    pub fn param_shape(&self, name: &str) -> Result<&[usize]> {
        self.params
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, s)| s.as_slice())
            .ok_or_else(|| anyhow!("variant {}: no param `{name}`", self.name))
    }

    pub fn total_params(&self) -> usize {
        self.params
            .iter()
            .map(|(_, s)| s.iter().product::<usize>())
            .sum()
    }
}

pub struct Registry {
    entries: HashMap<String, EntrySpec>,
    variants: HashMap<String, VariantMeta>,
}

const F32: &str = "float32";
const I32: &str = "int32";
/// dtype of the packed-expert argument handle (`Value::Packed`)
const PACKED: &str = "packed_experts";

fn arg(name: &str, shape: &[usize], dtype: &str) -> ArgSpec {
    ArgSpec {
        name: name.to_string(),
        shape: shape.to_vec(),
        dtype: dtype.to_string(),
    }
}

impl Registry {
    /// Build the registry from the rust-side constants alone — the exact
    /// mirror of aot.py's `build_entries` (same entry names, same input
    /// order, same shapes/dtypes), with no artifacts on disk.
    pub fn native() -> Registry {
        let cfgs = config::variants();
        let cfg0 = &cfgs[0]; // common dims (all variants share them)
        let (d, m, v) = (cfg0.d_model, cfg0.d_expert, cfg0.vocab);
        let (b, s, g) = (cfg0.batch, cfg0.seq, cfg0.group);
        let dd = cfg0.d_dense;
        let t = b * s;
        let ncal = 64;

        let mut entries: HashMap<String, EntrySpec> = HashMap::new();
        let mut add = |name: String, inputs: Vec<ArgSpec>| {
            entries.insert(name, EntrySpec { inputs });
        };

        // ---- shared inference blocks
        add(
            "shared/embed".into(),
            vec![
                arg("tokens", &[b, s], I32),
                arg("table", &[v, d], F32),
                arg("pos", &[s, d], F32),
            ],
        );
        add(
            "shared/attn_layer".into(),
            vec![
                arg("x", &[b, s, d], F32),
                arg("ln", &[d], F32),
                arg("wq", &[d, d], F32),
                arg("wk", &[d, d], F32),
                arg("wv", &[d, d], F32),
                arg("wo", &[d, d], F32),
            ],
        );
        add(
            "shared/dense_ffn".into(),
            vec![
                arg("x", &[b, s, d], F32),
                arg("ln", &[d], F32),
                arg("gate", &[d, dd], F32),
                arg("up", &[d, dd], F32),
                arg("down", &[dd, d], F32),
            ],
        );
        add(
            "shared/lm_head".into(),
            vec![
                arg("x", &[b, s, d], F32),
                arg("ln", &[d], F32),
                arg("head", &[d, v], F32),
            ],
        );

        // ---- hessian trace (per-expert FC flattened size d*m)
        let n = d * m;
        add(
            format!("shared/hvp_frob_n{n}"),
            vec![arg("w", &[n], F32), arg("v", &[n], F32)],
        );

        // ---- qdq + signround per (shape, bits)
        for (din, dout) in [(d, m), (m, d)] {
            let gg = if din >= g { din / g } else { 1 };
            for bits in [2u8, 3, 4, 8] {
                add(
                    format!("shared/qdq_{din}x{dout}_b{bits}"),
                    vec![
                        arg("w", &[din, dout], F32),
                        arg("v", &[din, dout], F32),
                        arg("alpha", &[gg, dout], F32),
                        arg("beta", &[gg, dout], F32),
                    ],
                );
            }
            for bits in config::MIXED_BITS {
                add(
                    format!("shared/signround_{din}x{dout}_b{bits}"),
                    vec![
                        arg("w", &[din, dout], F32),
                        arg("x", &[ncal, din], F32),
                        arg("v", &[din, dout], F32),
                        arg("alpha", &[gg, dout], F32),
                        arg("beta", &[gg, dout], F32),
                        arg("lr", &[], F32),
                    ],
                );
            }
        }

        // ---- packed dequant matmuls (serving hot path), one per
        // MoPEQ bit width; 4-bit keeps the original qmatmul4 name/shape
        for bits in [2u8, 3, 4, 8] {
            let wrows = crate::quant::pack::words_per_col(d, bits);
            add(
                format!("shared/qmatmul{bits}_{t}x{d}x{m}"),
                vec![
                    arg("x", &[t, d], F32),
                    arg("packed", &[wrows, m], I32),
                    arg("s", &[d / g, m], F32),
                    arg("zp", &[d / g, m], F32),
                ],
            );
        }

        // ---- standalone MoE-FFN kernel (pallas vs ref vs packed)
        for tag in ["pallas", "ref"] {
            add(
                format!("shared/moe_ffn_{tag}_e64"),
                vec![
                    arg("h", &[t, d], F32),
                    arg("gate", &[64, d, m], F32),
                    arg("up", &[64, d, m], F32),
                    arg("down", &[64, m, d], F32),
                ],
            );
        }
        add(
            "shared/moe_ffn_packed_e64".into(),
            vec![arg("h", &[t, d], F32), arg("experts", &[64], PACKED)],
        );

        // ---- moe_layer per routing signature
        let mut sigs: HashMap<String, config::ModelConfig> = HashMap::new();
        for cfg in &cfgs {
            sigs.entry(cfg.moe_signature()).or_insert_with(|| cfg.clone());
        }
        for (sig, cfg) in &sigs {
            let e = cfg.experts;
            let mut inputs = vec![
                arg("x", &[b, s, d], F32),
                arg("vis_mask", &[b, s], F32),
                arg("ln", &[d], F32),
                arg("router", &[e, d], F32),
                arg("gate", &[e, d, m], F32),
                arg("up", &[e, d, m], F32),
                arg("down", &[e, m, d], F32),
            ];
            if cfg.n_shared > 0 {
                let ds = cfg.d_shared;
                inputs.push(arg("sgate", &[d, ds], F32));
                inputs.push(arg("sup", &[d, ds], F32));
                inputs.push(arg("sdown", &[ds, d], F32));
            }
            for suffix in ["moe_layer", "moe_layer_pallas", "moe_layer_sparse"] {
                add(format!("{sig}/{suffix}"), inputs.clone());
            }
            // packed lowering: gate/up/down replaced by one bit-packed
            // expert handle (native backend; see moe::packed)
            let mut pinputs = vec![
                arg("x", &[b, s, d], F32),
                arg("vis_mask", &[b, s], F32),
                arg("ln", &[d], F32),
                arg("router", &[e, d], F32),
                arg("experts", &[e], PACKED),
            ];
            if cfg.n_shared > 0 {
                let ds = cfg.d_shared;
                pinputs.push(arg("sgate", &[d, ds], F32));
                pinputs.push(arg("sup", &[d, ds], F32));
                pinputs.push(arg("sdown", &[ds, d], F32));
            }
            add(format!("{sig}/moe_layer_packed"), pinputs);
        }

        // ---- train_step per variant
        for cfg in &cfgs {
            let bt = cfg.train_batch;
            let mut inputs: Vec<ArgSpec> = crate::moe::param_specs(cfg)
                .into_iter()
                .map(|(nm, sh)| arg(&nm, &sh, F32))
                .collect();
            inputs.push(arg("tokens", &[bt, cfg.seq], I32));
            inputs.push(arg("target", &[bt], I32));
            inputs.push(arg("lr", &[], F32));
            add(format!("{}/train_step", cfg.name), inputs.clone());
            add(format!("{}/train_step_sparse", cfg.name), inputs);
        }

        let variants = cfgs
            .iter()
            .map(|cfg| (cfg.name.to_string(), crate::moe::local_meta(cfg)))
            .collect();
        Registry { entries, variants }
    }

    /// Parse `artifacts/meta.json` and cross-check it against the rust
    /// constants (XLA backend path).
    pub fn load(root: &Path) -> Result<Registry> {
        let path = root.join("meta.json");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            anyhow!(
                "read {}: {e} — run `make artifacts` first",
                path.display()
            )
        })?;
        let json = Json::parse(&text)?;

        let mut entries = HashMap::new();
        for (name, e) in json.req("entries")?.as_obj()? {
            let inputs = e
                .req("inputs")?
                .as_arr()?
                .iter()
                .map(|i| {
                    Ok(ArgSpec {
                        name: i.req("name")?.as_str()?.to_string(),
                        shape: i.req("shape")?.shape()?,
                        dtype: i.req("dtype")?.as_str()?.to_string(),
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            entries.insert(name.clone(), EntrySpec { inputs });
        }

        let mut variants = HashMap::new();
        for (name, v) in json.req("variants")?.as_obj()? {
            // cross-check against the rust-side constants
            let cfg = config::variant(name)?;
            cfg.check_meta(v.req("config")?)?;
            let sig = v.req("moe_signature")?.as_str()?.to_string();
            if sig != cfg.moe_signature() {
                bail!("{name}: moe_signature mismatch");
            }
            let params = v
                .req("params")?
                .as_arr()?
                .iter()
                .map(|p| {
                    let pair = p.as_arr()?;
                    Ok((pair[0].as_str()?.to_string(), pair[1].shape()?))
                })
                .collect::<Result<Vec<_>>>()?;
            variants.insert(
                name.clone(),
                VariantMeta { name: name.clone(), moe_signature: sig, params },
            );
        }
        if variants.len() != config::variants().len() {
            bail!(
                "meta.json has {} variants, rust expects {}",
                variants.len(),
                config::variants().len()
            );
        }
        Ok(Registry { entries, variants })
    }

    pub fn entry(&self, name: &str) -> Result<&EntrySpec> {
        self.entries
            .get(name)
            .ok_or_else(|| anyhow!("unknown entry `{name}`"))
    }

    pub fn has_entry(&self, name: &str) -> bool {
        self.entries.contains_key(name)
    }

    pub fn variant(&self, name: &str) -> Result<&VariantMeta> {
        self.variants
            .get(name)
            .ok_or_else(|| anyhow!("unknown variant `{name}`"))
    }

    pub fn entry_names(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.entries.keys().map(|s| s.as_str()).collect();
        v.sort();
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;

    #[test]
    fn validate_catches_mismatches() {
        let spec = EntrySpec {
            inputs: vec![
                ArgSpec {
                    name: "x".into(),
                    shape: vec![2, 3],
                    dtype: "float32".into(),
                },
                ArgSpec {
                    name: "t".into(),
                    shape: vec![2],
                    dtype: "int32".into(),
                },
            ],
        };
        let ok: Vec<Value> = vec![
            Tensor::<f32>::zeros(&[2, 3]).into(),
            Tensor::<i32>::zeros(&[2]).into(),
        ];
        assert!(spec.validate(&ok).is_ok());
        // wrong arity
        assert!(spec.validate(&ok[..1]).is_err());
        // wrong shape
        let bad: Vec<Value> = vec![
            Tensor::<f32>::zeros(&[3, 2]).into(),
            Tensor::<i32>::zeros(&[2]).into(),
        ];
        assert!(spec.validate(&bad).is_err());
        // wrong dtype
        let bad2: Vec<Value> = vec![
            Tensor::<f32>::zeros(&[2, 3]).into(),
            Tensor::<f32>::zeros(&[2]).into(),
        ];
        assert!(spec.validate(&bad2).is_err());
    }

    #[test]
    fn native_registry_covers_the_aot_grid() {
        let r = Registry::native();
        // the variant-independent shared entries
        for e in [
            "shared/embed",
            "shared/attn_layer",
            "shared/dense_ffn",
            "shared/lm_head",
            "shared/hvp_frob_n2048",
            "shared/qdq_64x32_b2",
            "shared/qdq_32x64_b8",
            "shared/signround_64x32_b4",
            "shared/qmatmul2_128x64x32",
            "shared/qmatmul3_128x64x32",
            "shared/qmatmul4_128x64x32",
            "shared/qmatmul8_128x64x32",
            "shared/moe_ffn_ref_e64",
            "shared/moe_ffn_pallas_e64",
            "shared/moe_ffn_packed_e64",
        ] {
            assert!(r.has_entry(e), "missing {e}");
        }
        // one moe_layer quadruple per distinct routing signature
        for sig in ["moe_e64_k6_s1", "moe_e72_k6_s1", "moe_e64_k8_s0"] {
            for k in [
                "moe_layer",
                "moe_layer_pallas",
                "moe_layer_sparse",
                "moe_layer_packed",
            ] {
                assert!(r.has_entry(&format!("{sig}/{k}")), "missing {sig}/{k}");
            }
        }
        // packed specs: 3-bit packs 10 codes/word -> ceil(64/10) = 7
        // word rows; the expert handle is one packed arg
        let q3 = r.entry("shared/qmatmul3_128x64x32").unwrap();
        assert_eq!(q3.inputs[1].shape, vec![7, 32]);
        let pk = r.entry("moe_e64_k6_s1/moe_layer_packed").unwrap();
        assert_eq!(pk.inputs.len(), 8);
        assert_eq!(pk.inputs[4].dtype, "packed_experts");
        assert_eq!(pk.inputs[4].shape, vec![64]);
        assert_eq!(
            r.entry("moe_e64_k8_s0/moe_layer_packed").unwrap().inputs.len(),
            5
        );
        // train_step per variant
        for v in ["dsvl2_tiny", "dsvl2_small", "dsvl2_base", "molmoe"] {
            assert!(r.has_entry(&format!("{v}/train_step")));
            assert!(r.has_entry(&format!("{v}/train_step_sparse")));
            assert!(r.variant(v).is_ok());
        }
        // spec shape sanity: signround takes 6 args ending in a scalar lr
        let sr = r.entry("shared/signround_64x32_b2").unwrap();
        assert_eq!(sr.inputs.len(), 6);
        assert_eq!(sr.inputs[5].name, "lr");
        assert!(sr.inputs[5].shape.is_empty());
        // moe_layer with shared experts has 10 inputs, without has 7
        assert_eq!(r.entry("moe_e64_k6_s1/moe_layer").unwrap().inputs.len(), 10);
        assert_eq!(r.entry("moe_e64_k8_s0/moe_layer").unwrap().inputs.len(), 7);
    }

    #[test]
    fn native_variant_meta_matches_local_param_specs() {
        let r = Registry::native();
        let cfg = config::variant("dsvl2_tiny").unwrap();
        let meta = r.variant("dsvl2_tiny").unwrap();
        assert_eq!(meta.moe_signature, cfg.moe_signature());
        assert_eq!(meta.params, crate::moe::param_specs(&cfg));
        assert!(meta.param_shape("moe.gate").is_ok());
        assert!(meta.param_shape("nope").is_err());
    }
}
