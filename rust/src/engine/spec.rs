//! Calibration-aware quantization specs: the declarative types that
//! make the **full coordinator pipeline** expressible in the engine's
//! builder grammar.
//!
//! - [`QuantSpec`] = which quantization function fills the precision
//!   map ([`Quantizer`]: RTN / SignRound / GPTQ / AWQ) plus the
//!   [`CalibSpec`] describing the calibration capture the calibrated
//!   quantizers require. A calib-needing quantizer without a
//!   `CalibSpec` fails at `build()` with a typed
//!   [`SpecError::MissingCalib`] — never a silent RTN fallback, never
//!   a mid-warmup panic.
//! - [`AllocPolicy`] = how the per-expert bit allocation is computed:
//!   importance [`Metric`] × [`Granularity`] × bit `palette` ×
//!   optional [`AvgBitsBudget`]. `AllocPolicy::default()` is the
//!   paper's setting (closed-form Hessian sensitivity, model-wise
//!   K-means over {2,3,4}).
//! - [`Resolver`] = the shared resolution stage: metric → importance →
//!   Algorithm 2 → (optional) budget enforcement. The coordinator's
//!   table runner and `EngineBuilder::build` both call it, which is
//!   what makes their precision maps identical by construction.
//! - [`PreparedWeights`] = the whole pipeline
//!   (resolve → calibrate → allocate → quantize/pack → strip) run to
//!   completion: the execution-form weights plus the resolved map,
//!   its [`Provenance`], and the quantization stats.
//! - [`SavedMap`] = JSON (de)serialization of a precision map + its
//!   allocation provenance via [`crate::jsonx`], so
//!   `mopeq allocate --out map.json` →
//!   `PrecisionSource::MapFile(path)` round-trips a deployment.

use crate::cluster::{assign_map, enforce_budget, Granularity};
use crate::config::{ModelConfig, MIXED_BITS};
use crate::coordinator::executor::{ModelExecutor, MoeKernel, SharedArgs};
use crate::coordinator::quantize::{
    capture_calib, pack_experts, LayerCalib, QuantStats, Quantizer,
};
use crate::engine::{EngineWeights, PrecisionSource, WeightForm};
use crate::importance::{
    hessian_closed_form, hessian_hutchinson, hybrid, profile_frequency,
    ImportanceMap,
};
use crate::jsonx::Json;
use crate::moe::{PackedStore, PrecisionMap, WeightStore};
use crate::runtime::Session;
use anyhow::{anyhow, bail, Result};
use std::path::Path;
use std::sync::Arc;

/// How the Hessian-trace sensitivity (paper §3.3) is computed.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Estimator {
    /// exact trace under the Frobenius proxy, `(n-1)/‖W‖_F` — data-free
    /// and fast (the paper's values within estimator noise)
    ClosedForm,
    /// Algorithm 1: Hutchinson's estimator with `samples` Rademacher
    /// probes per FC layer, through the backend's HVP entry
    Hutchinson { samples: usize },
}

/// Expert-importance metric (paper §3) with its profiling knobs.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Metric {
    /// activation frequency over `batches` mixed-task calibration
    /// batches (§3.2)
    Frequency { batches: usize },
    /// Hessian-trace sensitivity (§3.3)
    Hessian(Estimator),
    /// normalized frequency × sensitivity (§3.4)
    Hybrid { batches: usize, estimator: Estimator },
}

impl Metric {
    /// Whether resolving this metric executes the model (and therefore
    /// needs a backend session). Only the closed-form Hessian is free.
    pub fn needs_model_runs(&self) -> bool {
        !matches!(self, Metric::Hessian(Estimator::ClosedForm))
    }

    /// Typed rejection of degenerate profiling knobs: zero batches or
    /// probes would produce an all-zero importance map, making the
    /// allocation arbitrary with no error.
    pub fn validate(&self) -> Result<()> {
        let knob = match self {
            Metric::Frequency { batches: 0 }
            | Metric::Hybrid { batches: 0, .. } => Some("batches"),
            Metric::Hessian(Estimator::Hutchinson { samples: 0 })
            | Metric::Hybrid {
                estimator: Estimator::Hutchinson { samples: 0 },
                ..
            } => Some("samples"),
            _ => None,
        };
        match knob {
            Some(knob) => {
                Err(SpecError::DegenerateMetric { knob }.into())
            }
            None => Ok(()),
        }
    }

    /// Human/provenance label.
    pub fn label(&self) -> String {
        fn est(e: &Estimator) -> String {
            match e {
                Estimator::ClosedForm => "closed-form".into(),
                Estimator::Hutchinson { samples } => {
                    format!("hutchinson m={samples}")
                }
            }
        }
        match self {
            Metric::Frequency { batches } => {
                format!("frequency(batches={batches})")
            }
            Metric::Hessian(e) => format!("hessian({})", est(e)),
            Metric::Hybrid { batches, estimator } => {
                format!("hybrid(batches={batches}, {})", est(estimator))
            }
        }
    }
}

/// Calibration capture: how many mixed-task batches to run with
/// hidden-state capture and how many token rows to subsample per MoE
/// layer (the coordinator's defaults).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CalibSpec {
    pub batches: usize,
    pub rows: usize,
}

impl Default for CalibSpec {
    fn default() -> Self {
        CalibSpec { batches: 16, rows: 256 }
    }
}

/// Which quantization function fills the precision map, plus the
/// calibration the calibrated quantizers (SignRound / GPTQ / AWQ)
/// require. The default is RTN (calibration-free).
#[derive(Clone, Debug, Default)]
pub struct QuantSpec {
    pub quantizer: Quantizer,
    pub calib: Option<CalibSpec>,
}

impl QuantSpec {
    /// Calibration-free round-to-nearest (the default).
    pub fn rtn() -> QuantSpec {
        QuantSpec { quantizer: Quantizer::Rtn, calib: None }
    }

    /// A calibrated quantizer with its capture spec.
    pub fn calibrated(quantizer: Quantizer, calib: CalibSpec) -> QuantSpec {
        QuantSpec { quantizer, calib: Some(calib) }
    }

    /// Typed validation of everything knowable from the spec alone —
    /// run before any session/executor work so a statically-invalid
    /// spec never pays for importance resolution first. `capture`
    /// re-checks the same conditions for direct callers.
    pub fn validate(&self) -> Result<()> {
        if !self.quantizer.needs_calib() {
            return Ok(());
        }
        let spec = self.calib.as_ref().ok_or_else(|| {
            SpecError::MissingCalib { quantizer: self.quantizer.label() }
        })?;
        if spec.batches == 0 || spec.rows == 0 {
            return Err(SpecError::EmptyCalib {
                batches: spec.batches,
                rows: spec.rows,
            }
            .into());
        }
        // SignRound's artifact has a static calib shape: fewer captured
        // rows than it expects must fail typed, not assert deep in the
        // row subsampler
        if let Quantizer::SignRound(sr) = &self.quantizer {
            if spec.rows < sr.calib_rows {
                return Err(SpecError::CalibRows {
                    rows: spec.rows,
                    needed: sr.calib_rows,
                }
                .into());
            }
        }
        Ok(())
    }

    /// Capture calibration (when the quantizer needs it) and quantize +
    /// pack every routed expert per the precision map — the **single**
    /// quantize stage both `EngineBuilder::build` and the coordinator
    /// drive, so their packed codes are bit-exact by construction.
    /// Calibration activations are captured from `ws` (the reference
    /// weights) at `seed ^ 0xCA11B`, exactly as the coordinator's table
    /// runner does.
    pub fn pack(
        &self,
        session: Option<&Session>,
        cfg: &ModelConfig,
        ws: &WeightStore,
        pmap: &PrecisionMap,
        kernel: MoeKernel,
        seed: u64,
    ) -> Result<(PackedStore, QuantStats)> {
        let calib = self.capture(session, cfg, ws, kernel, seed)?;
        pack_experts(session, cfg, ws, pmap, &self.quantizer, calib.as_ref())
    }

    /// The calibration-capture stage alone: `None` for calibration-free
    /// quantizers, a typed [`SpecError::MissingCalib`] when a
    /// calibrated quantizer has no [`CalibSpec`].
    pub fn capture(
        &self,
        session: Option<&Session>,
        cfg: &ModelConfig,
        ws: &WeightStore,
        kernel: MoeKernel,
        seed: u64,
    ) -> Result<Option<LayerCalib>> {
        if !self.quantizer.needs_calib() {
            return Ok(None);
        }
        self.validate()?;
        let spec = self.calib.as_ref().expect("validate checked calib");
        let session = session.ok_or_else(|| {
            anyhow!(
                "{} needs a backend session for calibration capture",
                self.quantizer.label()
            )
        })?;
        let exec = ModelExecutor::with_options(session, cfg, ws, kernel)?;
        Ok(Some(capture_calib(
            &exec,
            cfg,
            spec.batches,
            spec.rows,
            seed ^ 0xCA11B,
        )?))
    }
}

/// Upper bound on the mean assigned bits/expert: after Algorithm 2,
/// the least-important experts are demoted palette-step by
/// palette-step until the mean fits (the GEMQ-style global budget).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct AvgBitsBudget {
    pub max_mean_bits: f64,
}

/// The parameterized allocation policy — everything the paper ablates
/// (metric × granularity) plus the bit palette and an optional average
/// budget. `Default` is the paper's headline setting.
#[derive(Clone, Debug, PartialEq)]
pub struct AllocPolicy {
    pub metric: Metric,
    pub granularity: Granularity,
    /// candidate bit widths, strictly ascending (Algorithm 2 clusters
    /// into `palette.len()` groups)
    pub palette: Vec<u8>,
    pub budget: Option<AvgBitsBudget>,
}

impl Default for AllocPolicy {
    /// The paper's setting: closed-form Hessian sensitivity,
    /// model-wise K-means over {2, 3, 4} bits, no budget.
    fn default() -> Self {
        AllocPolicy {
            metric: Metric::Hessian(Estimator::ClosedForm),
            granularity: Granularity::ModelWise,
            palette: MIXED_BITS.to_vec(),
            budget: None,
        }
    }
}

impl AllocPolicy {
    /// Typed validation of the policy itself (no model access).
    pub fn validate(&self) -> Result<()> {
        self.metric.validate()?;
        let Some(&lo) = self.palette.first() else {
            return Err(SpecError::EmptyPalette.into());
        };
        if self.palette.windows(2).any(|w| w[1] <= w[0]) {
            return Err(SpecError::UnsortedPalette {
                palette: self.palette.clone(),
            }
            .into());
        }
        if let Some(&bad) =
            self.palette.iter().find(|&&b| b == 0 || b > 16)
        {
            return Err(SpecError::PaletteWidth { bits: bad }.into());
        }
        if let Some(budget) = &self.budget {
            if budget.max_mean_bits < lo as f64 {
                return Err(SpecError::InfeasibleBudget {
                    max_mean_bits: budget.max_mean_bits,
                    min_palette_bits: lo,
                }
                .into());
            }
        }
        Ok(())
    }
}

/// Where a precision map came from — serialized next to the map so a
/// deployment artifact is self-describing (re-running the recorded
/// metric × granularity × palette × budget reproduces the map).
#[derive(Clone, Debug, PartialEq)]
pub struct Provenance {
    pub metric: String,
    pub granularity: String,
    pub palette: Vec<u8>,
    /// the [`AvgBitsBudget`] cap the allocation was demoted under, if
    /// any — without it a budgeted map would not be reproducible from
    /// its own provenance
    pub budget: Option<f64>,
    pub mean_bits: f64,
    /// mean assigned bits per MoE layer
    pub layer_mean_bits: Vec<f64>,
}

/// Typed errors of the spec grammar — every invalid builder
/// combination fails at `build()` with one of these (downcast from the
/// returned `anyhow::Error`), before any worker thread is spawned.
#[derive(Clone, Debug, PartialEq)]
pub enum SpecError {
    /// `WeightForm::Fp16` combined with a quantizing precision source
    Fp16WithQuantizingSource,
    /// `WeightForm::Fp16` with a non-RTN quantizer configured — the
    /// spec would be silently ignored
    Fp16WithQuantizer { quantizer: &'static str },
    /// `PrecisionSource::Uniform(bits >= 16)` — that is the fp16
    /// reference, spelled `Reference`
    UniformIsFp16 { bits: u8 },
    /// `DequantizedF32` / `Packed` with `PrecisionSource::Reference`
    MissingPrecisionSource { form: &'static str },
    /// a calib-needing quantizer with no [`CalibSpec`]
    MissingCalib { quantizer: &'static str },
    /// the capture yields fewer calibration rows than the quantizer's
    /// static calib shape needs
    CalibRows { rows: usize, needed: usize },
    /// a calibration capture of zero batches or zero rows
    EmptyCalib { batches: usize, rows: usize },
    /// a profiling knob of zero (batches / probe samples) — the metric
    /// would be an all-zero map and the allocation arbitrary
    DegenerateMetric { knob: &'static str },
    EmptyPalette,
    UnsortedPalette { palette: Vec<u8> },
    PaletteWidth { bits: u8 },
    /// a supplied/loaded precision map contains an unquantizable width
    MapWidth { bits: u8 },
    /// budget below the smallest palette width — no allocation can fit
    InfeasibleBudget { max_mean_bits: f64, min_palette_bits: u8 },
    /// budget enforcement ran out of demotable experts: even with every
    /// palette width at the floor the mean stays above the cap (widths
    /// pinned outside the palette — e.g. fp16 experts — cannot be
    /// demoted)
    BudgetUnreachable { max_mean_bits: f64, floor_mean_bits: f64 },
    /// a loaded map names a different model variant
    VariantMismatch { expected: String, found: String },
}

impl std::fmt::Display for SpecError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SpecError::Fp16WithQuantizingSource => write!(
                f,
                "WeightForm::Fp16 serves the reference weights — use \
                 DequantizedF32 or Packed to apply a quantizing \
                 PrecisionSource"
            ),
            SpecError::Fp16WithQuantizer { quantizer } => write!(
                f,
                "WeightForm::Fp16 serves the reference weights \
                 unquantized — the configured {quantizer} QuantSpec \
                 would be silently ignored; use DequantizedF32 or \
                 Packed (or drop .quantizer())"
            ),
            SpecError::UniformIsFp16 { bits } => write!(
                f,
                "PrecisionSource::Uniform({bits}) is the fp16 \
                 reference — use WeightForm::Fp16 with \
                 PrecisionSource::Reference"
            ),
            SpecError::MissingPrecisionSource { form } => write!(
                f,
                "WeightForm::{form} needs a quantizing PrecisionSource \
                 (Uniform / Map / MapFile / Allocated)"
            ),
            SpecError::MissingCalib { quantizer } => write!(
                f,
                "{quantizer} needs calibration data — attach a CalibSpec \
                 via QuantSpec::calibrated({quantizer}, CalibSpec {{ .. }})"
            ),
            SpecError::CalibRows { rows, needed } => write!(
                f,
                "CalibSpec captures {rows} rows but the quantizer's \
                 calibration shape needs at least {needed} — raise \
                 CalibSpec.rows (or lower SignRoundConfig.calib_rows)"
            ),
            SpecError::EmptyCalib { batches, rows } => write!(
                f,
                "CalibSpec {{ batches: {batches}, rows: {rows} }} \
                 captures no calibration data — both must be non-zero"
            ),
            SpecError::DegenerateMetric { knob } => write!(
                f,
                "importance metric has {knob} = 0 — the map would be \
                 all zeros and the allocation arbitrary"
            ),
            SpecError::EmptyPalette => {
                write!(f, "AllocPolicy palette is empty")
            }
            SpecError::UnsortedPalette { palette } => write!(
                f,
                "AllocPolicy palette {palette:?} must be strictly \
                 ascending"
            ),
            SpecError::PaletteWidth { bits } => write!(
                f,
                "palette width {bits} is outside the quantizable range \
                 1..=16"
            ),
            SpecError::MapWidth { bits } => write!(
                f,
                "precision map contains width {bits}, outside the \
                 quantizable range 1..=16"
            ),
            SpecError::InfeasibleBudget {
                max_mean_bits,
                min_palette_bits,
            } => write!(
                f,
                "budget of {max_mean_bits} mean bits/expert is \
                 infeasible: the smallest palette width is \
                 {min_palette_bits}"
            ),
            SpecError::BudgetUnreachable {
                max_mean_bits,
                floor_mean_bits,
            } => write!(
                f,
                "budget of {max_mean_bits} mean bits/expert is \
                 unreachable: demoting every palette-width expert to \
                 the floor still leaves a mean of {floor_mean_bits} \
                 (non-palette widths cannot be demoted)"
            ),
            SpecError::VariantMismatch { expected, found } => write!(
                f,
                "precision map is for `{found}`, engine variant is \
                 `{expected}`"
            ),
        }
    }
}

impl std::error::Error for SpecError {}

/// The shared resolution stage over one model's reference weights:
/// metric → importance map → Algorithm 2 (at the policy's granularity
/// and palette) → optional budget enforcement. `EngineBuilder::build`,
/// the coordinator's table runner, and the CLI all allocate through
/// this one type, so a given `(weights, seed, policy)` yields the
/// identical [`PrecisionMap`] on every path.
pub struct Resolver<'a> {
    session: Option<&'a Session>,
    cfg: &'a ModelConfig,
    ws: &'a WeightStore,
    seed: u64,
    kernel: MoeKernel,
}

impl<'a> Resolver<'a> {
    pub fn new(
        session: &'a Session,
        cfg: &'a ModelConfig,
        ws: &'a WeightStore,
        seed: u64,
    ) -> Resolver<'a> {
        Resolver {
            session: Some(session),
            cfg,
            ws,
            seed,
            kernel: MoeKernel::default(),
        }
    }

    /// A resolver without a backend session — only the data-free
    /// closed-form Hessian metric resolves; anything that must execute
    /// the model errors.
    pub fn sessionless(
        cfg: &'a ModelConfig,
        ws: &'a WeightStore,
        seed: u64,
    ) -> Resolver<'a> {
        Resolver { session: None, cfg, ws, seed, kernel: MoeKernel::default() }
    }

    /// Select the MoE-layer lowering profiling runs execute (the
    /// coordinator threads its `--sparse` choice through here).
    pub fn with_kernel(mut self, kernel: MoeKernel) -> Resolver<'a> {
        self.kernel = kernel;
        self
    }

    fn session(&self) -> Result<&'a Session> {
        self.session.ok_or_else(|| {
            anyhow!(
                "this importance metric executes the model and needs a \
                 backend session (only Metric::Hessian(ClosedForm) is \
                 data-free)"
            )
        })
    }

    fn executor(&self) -> Result<ModelExecutor<'a>> {
        ModelExecutor::with_options(
            self.session()?,
            self.cfg,
            self.ws,
            self.kernel,
        )
    }

    fn frequency(&self, batches: usize) -> Result<ImportanceMap> {
        Ok(profile_frequency(&self.executor()?, self.cfg, batches, self.seed)?
            .total)
    }

    fn hessian(&self, est: &Estimator) -> Result<ImportanceMap> {
        match est {
            Estimator::ClosedForm => hessian_closed_form(self.ws, self.cfg),
            Estimator::Hutchinson { samples } => hessian_hutchinson(
                self.session()?,
                self.ws,
                self.cfg,
                *samples,
                self.seed,
            ),
        }
    }

    /// Resolve a metric into its per-expert importance map.
    pub fn importance(&self, metric: &Metric) -> Result<ImportanceMap> {
        match metric {
            Metric::Frequency { batches } => self.frequency(*batches),
            Metric::Hessian(est) => self.hessian(est),
            Metric::Hybrid { batches, estimator } => {
                let af = self.frequency(*batches)?;
                let h = self.hessian(estimator)?;
                Ok(hybrid(&af, &h))
            }
        }
    }

    /// The allocation stage: validate → importance → Algorithm 2 →
    /// budget. Returns the map plus its provenance record.
    pub fn allocate(
        &self,
        policy: &AllocPolicy,
    ) -> Result<(PrecisionMap, Provenance)> {
        policy.validate()?;
        let imp = self.importance(&policy.metric)?;
        let mut bits = assign_map(
            &imp.values,
            &policy.palette,
            policy.granularity,
            self.seed,
        );
        if let Some(budget) = &policy.budget {
            enforce_budget(
                &mut bits,
                &imp.values,
                &policy.palette,
                budget.max_mean_bits,
            )?;
        }
        let map = PrecisionMap { bits };
        let provenance = Provenance {
            metric: policy.metric.label(),
            granularity: policy.granularity.label().to_string(),
            palette: policy.palette.clone(),
            budget: policy.budget.map(|b| b.max_mean_bits),
            mean_bits: map.mean_bits(),
            layer_mean_bits: map.layer_mean_bits(),
        };
        Ok((map, provenance))
    }
}

/// A precision map + provenance as a JSON artifact: what
/// `mopeq allocate --out map.json` writes and
/// `PrecisionSource::MapFile` loads. The map's `bits` round-trip
/// byte-for-byte (integers), so allocate → serve reproduces the exact
/// deployment.
#[derive(Clone, Debug, PartialEq)]
pub struct SavedMap {
    pub variant: String,
    pub map: PrecisionMap,
    pub provenance: Option<Provenance>,
}

impl SavedMap {
    pub fn to_json(&self) -> Json {
        let bits = Json::Arr(
            self.map
                .bits
                .iter()
                .map(|row| {
                    Json::Arr(
                        row.iter().map(|&b| Json::Num(b as f64)).collect(),
                    )
                })
                .collect(),
        );
        let provenance = match &self.provenance {
            None => Json::Null,
            Some(p) => Json::Obj(vec![
                ("metric".into(), Json::Str(p.metric.clone())),
                ("granularity".into(), Json::Str(p.granularity.clone())),
                (
                    "palette".into(),
                    Json::Arr(
                        p.palette
                            .iter()
                            .map(|&b| Json::Num(b as f64))
                            .collect(),
                    ),
                ),
                (
                    "budget".into(),
                    p.budget.map_or(Json::Null, Json::Num),
                ),
                ("mean_bits".into(), Json::Num(p.mean_bits)),
                (
                    "layer_mean_bits".into(),
                    Json::Arr(
                        p.layer_mean_bits
                            .iter()
                            .map(|&v| Json::Num(v))
                            .collect(),
                    ),
                ),
            ]),
        };
        Json::Obj(vec![
            ("variant".into(), Json::Str(self.variant.clone())),
            ("bits".into(), bits),
            ("provenance".into(), provenance),
        ])
    }

    pub fn from_json(j: &Json) -> Result<SavedMap> {
        let variant = j.req("variant")?.as_str()?.to_string();
        let mut bits = Vec::new();
        for row in j.req("bits")?.as_arr()? {
            let mut r = Vec::new();
            for v in row.as_arr()? {
                let b = v.as_usize()?;
                if b > u8::MAX as usize {
                    bail!("bit width {b} is out of range");
                }
                r.push(b as u8);
            }
            bits.push(r);
        }
        let provenance = match j.get("provenance") {
            None | Some(Json::Null) => None,
            Some(p) => Some(Provenance {
                metric: p.req("metric")?.as_str()?.to_string(),
                granularity: p.req("granularity")?.as_str()?.to_string(),
                palette: p
                    .req("palette")?
                    .as_arr()?
                    .iter()
                    .map(|v| Ok(v.as_usize()? as u8))
                    .collect::<Result<_>>()?,
                budget: match p.get("budget") {
                    None | Some(Json::Null) => None,
                    Some(b) => Some(b.as_f64()?),
                },
                mean_bits: p.req("mean_bits")?.as_f64()?,
                layer_mean_bits: p
                    .req("layer_mean_bits")?
                    .as_arr()?
                    .iter()
                    .map(|v| v.as_f64())
                    .collect::<Result<_>>()?,
            }),
        };
        Ok(SavedMap { variant, map: PrecisionMap { bits }, provenance })
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_json().to_string())
            .map_err(|e| anyhow!("write {}: {e}", path.display()))
    }

    pub fn load(path: &Path) -> Result<SavedMap> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow!("read {}: {e}", path.display()))?;
        SavedMap::from_json(&Json::parse(&text)?)
    }
}

/// The full weight-preparation pipeline run to completion:
/// resolve → calibrate → allocate → quantize/pack → strip. This is the
/// single path `EngineBuilder::build` executes; the coordinator drives
/// the same stages ([`Resolver::allocate`], [`QuantSpec::pack`])
/// against its own evaluation scratch stores.
pub struct PreparedWeights {
    pub(crate) weights: EngineWeights,
    /// the resolved per-expert map (`None` for the fp16 reference)
    pub pmap: Option<PrecisionMap>,
    /// allocation provenance (`Some` for `Allocated` sources and for
    /// `MapFile`s that carry one)
    pub provenance: Option<Provenance>,
    /// quantization stats (`Some` whenever experts were quantized)
    pub stats: Option<QuantStats>,
}

impl PreparedWeights {
    /// Run the pipeline (profiling/calibration runs use the default MoE
    /// lowering, like the engine's workers). `open` is called at most
    /// once, and only when a stage actually executes the model
    /// (profiling metrics, Hutchinson probes, calibration capture) —
    /// fp16 / RTN / closed-form builds stay session-free.
    pub(crate) fn prepare(
        cfg: &ModelConfig,
        mut ws: WeightStore,
        form: WeightForm,
        precision: &PrecisionSource,
        quant: &QuantSpec,
        seed: u64,
        open: impl FnOnce() -> Result<Session>,
    ) -> Result<PreparedWeights> {
        let kernel = MoeKernel::default();
        // -- validation first: typed errors, before any session,
        // executor, or worker work. Uniform(>=16) is checked ahead of
        // the form grid so `Fp16 × Uniform(16)` gets the actionable
        // advice (use Reference), not a misleading form error.
        if let PrecisionSource::Uniform(bits) = precision {
            if *bits >= 16 {
                return Err(SpecError::UniformIsFp16 { bits: *bits }.into());
            }
        }
        let quantizing = !matches!(precision, PrecisionSource::Reference);
        match form {
            WeightForm::Fp16 if quantizing => {
                return Err(SpecError::Fp16WithQuantizingSource.into());
            }
            WeightForm::DequantizedF32 | WeightForm::Packed
                if !quantizing =>
            {
                return Err(SpecError::MissingPrecisionSource {
                    form: form.label(),
                }
                .into());
            }
            _ => {}
        }
        if form == WeightForm::Fp16 {
            // a non-RTN quantizer on an fp16 build would be silently
            // ignored — the no-silent-fallback contract forbids that
            if !matches!(quant.quantizer, Quantizer::Rtn) {
                return Err(SpecError::Fp16WithQuantizer {
                    quantizer: quant.quantizer.label(),
                }
                .into());
            }
        } else {
            // everything knowable from the quant spec alone (missing /
            // empty / too-small CalibSpec) fails here, before any
            // session is opened or importance resolved
            quant.validate()?;
        }
        if let PrecisionSource::Allocated(policy) = precision {
            policy.validate()?;
        }
        if let PrecisionSource::Searched(spec) = precision {
            spec.validate()?;
        }

        // -- open a session only when a stage executes the model
        let needs_runs = matches!(
            precision,
            PrecisionSource::Allocated(p) if p.metric.needs_model_runs()
        ) || matches!(
            precision,
            PrecisionSource::Searched(s) if s.needs_model_runs()
        ) || (form != WeightForm::Fp16 && quant.quantizer.needs_calib());
        let session = if needs_runs { Some(open()?) } else { None };

        // -- resolve the precision source into a map (+ provenance)
        let (pmap, provenance) = match precision {
            PrecisionSource::Reference => (None, None),
            PrecisionSource::Uniform(bits) => {
                let map = PrecisionMap::uniform(cfg, *bits);
                // same width validation as supplied maps: Uniform(0)
                // would otherwise quantize to NaN weights
                check_map(cfg, &map)?;
                (Some(map), None)
            }
            PrecisionSource::Map(map) => {
                check_map(cfg, map)?;
                (Some(map.clone()), None)
            }
            PrecisionSource::MapFile(path) => {
                let saved = SavedMap::load(path)?;
                if saved.variant != cfg.name {
                    return Err(SpecError::VariantMismatch {
                        expected: cfg.name.to_string(),
                        found: saved.variant,
                    }
                    .into());
                }
                check_map(cfg, &saved.map)?;
                (Some(saved.map), saved.provenance)
            }
            PrecisionSource::Allocated(policy) => {
                let resolver = Resolver {
                    session: session.as_ref(),
                    cfg,
                    ws: &ws,
                    seed,
                    kernel,
                };
                let (map, prov) = resolver.allocate(policy)?;
                (Some(map), Some(prov))
            }
            PrecisionSource::Searched(spec) => {
                let out = crate::search::run_search(
                    session.as_ref(),
                    cfg,
                    &ws,
                    spec,
                    seed,
                )?;
                (Some(out.map), Some(out.provenance))
            }
        };

        // -- calibrate → quantize/pack → strip into the execution form
        let mut stats = None;
        let weights = match form {
            WeightForm::Fp16 => {
                EngineWeights::Dense(Arc::new(SharedArgs::new(&ws)))
            }
            WeightForm::DequantizedF32 | WeightForm::Packed => {
                let map = pmap.as_ref().expect("validated quantizing source");
                let (store, st) = quant.pack(
                    session.as_ref(),
                    cfg,
                    &ws,
                    map,
                    kernel,
                    seed,
                )?;
                stats = Some(st);
                if form == WeightForm::DequantizedF32 {
                    store.write_dequantized(&mut ws)?;
                    EngineWeights::Dense(Arc::new(SharedArgs::new(&ws)))
                } else {
                    ws.strip_experts();
                    EngineWeights::Packed {
                        backbone: Arc::new(SharedArgs::new(&ws)),
                        experts: Arc::new(store),
                    }
                }
            }
        };
        Ok(PreparedWeights { weights, pmap, provenance, stats })
    }
}

/// Shape + width validation of a supplied/loaded precision map: a
/// corrupt artifact (e.g. a 0-bit entry, which would quantize every
/// weight to its zero-point) must fail at build, not serve garbage.
/// Also the reload path's admission gate (`ReloadHandle::reload`).
pub(crate) fn check_map(cfg: &ModelConfig, pmap: &PrecisionMap) -> Result<()> {
    if pmap.bits.len() != cfg.moe_layers()
        || pmap.bits.iter().any(|l| l.len() != cfg.experts)
    {
        bail!(
            "precision map shape {}x{} != config {}x{}",
            pmap.bits.len(),
            pmap.bits.first().map_or(0, |l| l.len()),
            cfg.moe_layers(),
            cfg.experts
        );
    }
    if let Some((_, bad)) =
        pmap.iter_experts().find(|&(_, b)| b == 0 || b > 16)
    {
        return Err(SpecError::MapWidth { bits: bad }.into());
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config;
    use crate::moe::local_meta;

    #[test]
    fn default_policy_is_the_paper_setting() {
        let p = AllocPolicy::default();
        assert_eq!(p.metric, Metric::Hessian(Estimator::ClosedForm));
        assert_eq!(p.granularity, Granularity::ModelWise);
        assert_eq!(p.palette, MIXED_BITS.to_vec());
        assert!(p.budget.is_none());
        p.validate().unwrap();
    }

    #[test]
    fn palette_validation_is_typed() {
        let mut p = AllocPolicy { palette: vec![], ..Default::default() };
        let err = p.validate().unwrap_err();
        assert_eq!(
            err.downcast_ref::<SpecError>(),
            Some(&SpecError::EmptyPalette)
        );
        p.palette = vec![4, 2, 3];
        let err = p.validate().unwrap_err();
        assert!(matches!(
            err.downcast_ref::<SpecError>(),
            Some(SpecError::UnsortedPalette { .. })
        ));
        p.palette = vec![2, 2, 4];
        assert!(p.validate().is_err(), "duplicates are not ascending");
        p.palette = vec![0, 2];
        assert!(matches!(
            p.validate().unwrap_err().downcast_ref::<SpecError>(),
            Some(SpecError::PaletteWidth { bits: 0 })
        ));
        p.palette = vec![2, 3, 4];
        p.budget = Some(AvgBitsBudget { max_mean_bits: 1.5 });
        assert!(matches!(
            p.validate().unwrap_err().downcast_ref::<SpecError>(),
            Some(SpecError::InfeasibleBudget { .. })
        ));
        p.budget = Some(AvgBitsBudget { max_mean_bits: 2.0 });
        p.validate().unwrap();
    }

    #[test]
    fn sessionless_resolver_allocates_closed_form_only() {
        let cfg = config::variant("dsvl2_tiny").unwrap();
        let ws = WeightStore::init(&cfg, &local_meta(&cfg), 3);
        let r = Resolver::sessionless(&cfg, &ws, 3);
        let (map, prov) = r.allocate(&AllocPolicy::default()).unwrap();
        assert_eq!(map.bits.len(), cfg.moe_layers());
        assert!(prov.metric.contains("hessian"));
        assert_eq!(prov.layer_mean_bits.len(), cfg.moe_layers());
        // data-driven metrics need a session
        let err = r
            .allocate(&AllocPolicy {
                metric: Metric::Frequency { batches: 1 },
                ..Default::default()
            })
            .unwrap_err();
        assert!(err.to_string().contains("session"), "{err}");
    }

    #[test]
    fn saved_map_json_roundtrip_is_exact() {
        let saved = SavedMap {
            variant: "dsvl2_tiny".into(),
            map: PrecisionMap {
                bits: vec![vec![2, 3, 4, 16], vec![4, 4, 2, 3]],
            },
            provenance: Some(Provenance {
                metric: "hessian(closed-form)".into(),
                granularity: "Model-wise".into(),
                palette: vec![2, 3, 4],
                budget: Some(2.5),
                mean_bits: 5.25,
                layer_mean_bits: vec![6.25, 3.25],
            }),
        };
        let json = saved.to_json().to_string();
        let back = SavedMap::from_json(&Json::parse(&json).unwrap()).unwrap();
        assert_eq!(back, saved);
        // budget-free provenance round-trips as null
        let mut unbudgeted = saved.clone();
        unbudgeted.provenance.as_mut().unwrap().budget = None;
        let back = SavedMap::from_json(
            &Json::parse(&unbudgeted.to_json().to_string()).unwrap(),
        )
        .unwrap();
        assert_eq!(back, unbudgeted);
        // and without provenance entirely
        let bare = SavedMap { provenance: None, ..saved };
        let back =
            SavedMap::from_json(&Json::parse(&bare.to_json().to_string())
                .unwrap())
            .unwrap();
        assert_eq!(back, bare);
    }

    #[test]
    fn missing_calib_is_a_typed_error() {
        let cfg = config::variant("dsvl2_tiny").unwrap();
        let ws = WeightStore::init(&cfg, &local_meta(&cfg), 0);
        let spec = QuantSpec {
            quantizer: Quantizer::Gptq { damp: 0.01 },
            calib: None,
        };
        let err = spec
            .capture(None, &cfg, &ws, MoeKernel::default(), 0)
            .unwrap_err();
        assert_eq!(
            err.downcast_ref::<SpecError>(),
            Some(&SpecError::MissingCalib { quantizer: "GPTQ" })
        );
    }

    #[test]
    fn zero_profiling_knobs_are_typed_errors() {
        for metric in [
            Metric::Frequency { batches: 0 },
            Metric::Hessian(Estimator::Hutchinson { samples: 0 }),
            Metric::Hybrid {
                batches: 0,
                estimator: Estimator::ClosedForm,
            },
            Metric::Hybrid {
                batches: 4,
                estimator: Estimator::Hutchinson { samples: 0 },
            },
        ] {
            let p = AllocPolicy { metric, ..Default::default() };
            assert!(matches!(
                p.validate().unwrap_err().downcast_ref::<SpecError>(),
                Some(SpecError::DegenerateMetric { .. })
            ));
        }
    }

    #[test]
    fn empty_calib_capture_is_a_typed_error() {
        let cfg = config::variant("dsvl2_tiny").unwrap();
        let ws = WeightStore::init(&cfg, &local_meta(&cfg), 0);
        let spec = QuantSpec::calibrated(
            Quantizer::Gptq { damp: 0.01 },
            CalibSpec { batches: 2, rows: 0 },
        );
        let err = spec
            .capture(None, &cfg, &ws, MoeKernel::default(), 0)
            .unwrap_err();
        assert_eq!(
            err.downcast_ref::<SpecError>(),
            Some(&SpecError::EmptyCalib { batches: 2, rows: 0 })
        );
    }

    #[test]
    fn signround_with_too_few_calib_rows_is_a_typed_error() {
        use crate::coordinator::SignRoundConfig;
        let cfg = config::variant("dsvl2_tiny").unwrap();
        let ws = WeightStore::init(&cfg, &local_meta(&cfg), 0);
        // SignRound's artifact wants 64 calib rows; capturing only 32
        // must fail typed at the capture stage, not assert inside the
        // row subsampler mid-build
        let spec = QuantSpec::calibrated(
            Quantizer::SignRound(SignRoundConfig::default()),
            CalibSpec { batches: 2, rows: 32 },
        );
        let err = spec
            .capture(None, &cfg, &ws, MoeKernel::default(), 0)
            .unwrap_err();
        assert_eq!(
            err.downcast_ref::<SpecError>(),
            Some(&SpecError::CalibRows { rows: 32, needed: 64 })
        );
    }
}
