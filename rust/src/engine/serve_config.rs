//! Declarative serving deployments: [`ServeConfig`] collapses the
//! `mopeq serve` flag sprawl (`--packed/--map/--quantizer/--workers/
//! --queue-depth/--linger-ms/…`) into one struct with jsonx load/save —
//! `mopeq serve --config serve.json` (flags override the file), and
//! [`EngineBuilder::from_config`] so the CLI, the tests, and the
//! network front-end all construct engines through the **identical**
//! decision tree:
//!
//! - `map` set → [`PrecisionSource::MapFile`] (conflicting allocation
//!   fields fail typed — a map file IS the allocation);
//! - `packed` or any allocation field set →
//!   [`PrecisionSource::Allocated`] with the same flag semantics
//!   `mopeq allocate` has (no field = the paper default);
//! - otherwise the fp16 reference.
//!
//! Unknown JSON keys fail typed (the config-file equivalent of the
//! CLI's `check_known` typo guard); missing keys take their defaults,
//! so a hand-written `{"model": "molmoe", "packed": true}` is a
//! complete config.

use crate::cli::Args;
use crate::cluster::Granularity;
use crate::coordinator::{Quantizer, SignRoundConfig};
use crate::engine::spec::{
    AllocPolicy, AvgBitsBudget, CalibSpec, Estimator, Metric, QuantSpec,
};
use crate::engine::{Engine, EngineBuilder, PrecisionSource, WeightForm};
use crate::jsonx::Json;
use crate::serve::BatchPolicy;
use anyhow::{anyhow, bail, Result};
use std::path::{Path, PathBuf};
use std::time::Duration;

/// One serving deployment, declaratively: what `mopeq serve`'s flags
/// describe, as a saveable/loadable value.
#[derive(Clone, Debug, PartialEq)]
pub struct ServeConfig {
    pub model: String,
    pub seed: u64,
    /// serve straight from bit-packed codes (`WeightForm::Packed`);
    /// false with a quantizing source = the legacy qdq→f32 form
    pub packed: bool,
    /// a `SavedMap` JSON artifact (`mopeq allocate --out`) — exclusive
    /// with the allocation fields below
    pub map: Option<PathBuf>,
    /// `rtn` | `signround` | `gptq` | `awq`
    pub quantizer: String,
    /// GPTQ relative dampening (used only by `quantizer = "gptq"`)
    pub damp: f64,
    /// AWQ scaling exponent (used only by `quantizer = "awq"`)
    pub alpha: f64,
    pub calib_batches: usize,
    pub calib_rows: usize,
    /// `frequency` | `hessian` | `hybrid`; `None` = the paper default
    /// (closed-form Hessian)
    pub metric: Option<String>,
    /// `layer` | `model`; `None` = model-wise
    pub granularity: Option<String>,
    /// candidate bit widths; `None` = the paper's {2,3,4}
    pub palette: Option<Vec<u8>>,
    /// average-bits cap ([`AvgBitsBudget`])
    pub budget: Option<f64>,
    /// Hutchinson probes when `metric` uses the estimator
    pub hutchinson_samples: usize,
    /// use the exact closed-form trace instead of Hutchinson
    pub closed_form_hessian: bool,
    pub workers: usize,
    pub queue_depth: usize,
    pub linger_ms: u64,
    /// request-trace ring capacity (`GET /v1/traces` window)
    pub trace_buffer: usize,
    /// keep 1 in `trace_sample` completed request traces (1 = all)
    pub trace_sample: usize,
    /// bound the packed expert resident set to this many heap bytes —
    /// experts spill to the tiered store's disk artifact and page in
    /// on demand. Requires `packed`.
    pub resident_bytes: Option<usize>,
    /// where the tiered store's artifact file lives (kept on disk for
    /// reuse); `None` = a per-engine temp file, deleted on shutdown.
    /// Only applies with `resident_bytes`.
    pub store_path: Option<PathBuf>,
    /// background predictive prefetch for the tiered store (default
    /// on; `false` = demand paging only)
    pub prefetch: bool,
    /// `addr:port` for the HTTP front-end (`mopeq serve --listen`);
    /// `None` = the in-process demo loop
    pub listen: Option<String>,
    /// retain what a live precision-map hot-swap needs
    /// (`EngineBuilder::reloadable`) so `POST /v1/reload` works.
    /// Requires `packed`; implied by `adapt_dir`.
    pub reloadable: bool,
    /// frontier candidate directory (`mopeq search --frontier-out`) for
    /// the background adapt controller (`mopeq serve --adapt`); implies
    /// `reloadable`
    pub adapt_dir: Option<PathBuf>,
    /// seconds between the adapt controller's routing observations
    pub adapt_interval_secs: u64,
    /// shadow-probe 1 in N completed requests on the retained dense
    /// reference (`GET /v1/quality`); 0 = off. Requires a packed
    /// deployment (the dense weights are retained via the reload path).
    pub quality_sample: usize,
    /// SLO: p99 latency objective in milliseconds (`/healthz` grading)
    pub slo_p99_ms: Option<f64>,
    /// SLO: highest acceptable rejection rate, 0..=1
    pub slo_max_reject: Option<f64>,
    /// SLO: lowest acceptable shadow-probe top-1 agreement, 0..=1
    /// (needs `quality_sample`)
    pub slo_min_agreement: Option<f64>,
}

impl Default for ServeConfig {
    fn default() -> Self {
        let calib = CalibSpec::default();
        ServeConfig {
            model: "dsvl2_tiny".into(),
            seed: 0,
            packed: false,
            map: None,
            quantizer: "rtn".into(),
            damp: 0.01,
            alpha: 0.5,
            calib_batches: calib.batches,
            calib_rows: calib.rows,
            metric: None,
            granularity: None,
            palette: None,
            budget: None,
            hutchinson_samples: 8,
            closed_form_hessian: false,
            workers: 1,
            queue_depth: 128,
            linger_ms: 2,
            trace_buffer: 256,
            trace_sample: 1,
            resident_bytes: None,
            store_path: None,
            prefetch: true,
            listen: None,
            reloadable: false,
            adapt_dir: None,
            adapt_interval_secs: 10,
            quality_sample: 0,
            slo_p99_ms: None,
            slo_max_reject: None,
            slo_min_agreement: None,
        }
    }
}

impl ServeConfig {
    /// Whether any allocation field is set — the config equivalent of
    /// the CLI's "any allocation flag present means the user asked for
    /// an allocated map".
    pub fn has_alloc(&self) -> bool {
        self.metric.is_some()
            || self.granularity.is_some()
            || self.palette.is_some()
            || self.budget.is_some()
    }

    fn spec_metric(&self) -> Result<Metric> {
        let estimator = if self.closed_form_hessian {
            Estimator::ClosedForm
        } else {
            Estimator::Hutchinson { samples: self.hutchinson_samples }
        };
        Ok(match self.metric.as_deref() {
            None => AllocPolicy::default().metric,
            Some("frequency") | Some("af") => {
                Metric::Frequency { batches: self.calib_batches }
            }
            Some("hessian") => Metric::Hessian(estimator),
            Some("hybrid") => Metric::Hybrid {
                batches: self.calib_batches,
                estimator,
            },
            Some(m) => {
                bail!("unknown metric `{m}` (frequency|hessian|hybrid)")
            }
        })
    }

    fn alloc_policy(&self) -> Result<AllocPolicy> {
        let granularity = match self.granularity.as_deref() {
            None | Some("model") => Granularity::ModelWise,
            Some("layer") => Granularity::LayerWise,
            Some(g) => bail!("unknown granularity `{g}` (layer|model)"),
        };
        Ok(AllocPolicy {
            metric: self.spec_metric()?,
            granularity,
            palette: self
                .palette
                .clone()
                .unwrap_or_else(|| AllocPolicy::default().palette),
            budget: self
                .budget
                .map(|max_mean_bits| AvgBitsBudget { max_mean_bits }),
        })
    }

    /// The precision source this config describes (the serve decision
    /// tree — see the module docs).
    pub fn precision(&self) -> Result<PrecisionSource> {
        if let Some(map) = &self.map {
            if self.has_alloc() {
                bail!(
                    "`map` loads a finished allocation; drop metric/\
                     granularity/palette/budget (or drop `map` to \
                     allocate from those fields)"
                );
            }
            return Ok(PrecisionSource::MapFile(map.clone()));
        }
        if self.packed || self.has_alloc() {
            return Ok(PrecisionSource::Allocated(self.alloc_policy()?));
        }
        Ok(PrecisionSource::Reference)
    }

    /// The weight form: packed when asked, fp16 for the bare reference,
    /// qdq→f32 for a quantizing source without `packed`.
    pub fn weight_form(&self) -> Result<WeightForm> {
        Ok(if self.packed {
            WeightForm::Packed
        } else if matches!(self.precision()?, PrecisionSource::Reference) {
            WeightForm::Fp16
        } else {
            WeightForm::DequantizedF32
        })
    }

    /// The quantization spec (`quantizer` + calibration capture).
    pub fn quant_spec(&self) -> Result<QuantSpec> {
        let quantizer = match self.quantizer.as_str() {
            "rtn" => Quantizer::Rtn,
            "signround" => Quantizer::SignRound(SignRoundConfig::default()),
            "gptq" => Quantizer::Gptq { damp: self.damp },
            "awq" => Quantizer::Awq { alpha: self.alpha as f32 },
            q => bail!("unknown quantizer `{q}` (rtn|signround|gptq|awq)"),
        };
        let calib = quantizer.needs_calib().then_some(CalibSpec {
            batches: self.calib_batches,
            rows: self.calib_rows,
        });
        Ok(QuantSpec { quantizer, calib })
    }

    /// Whether the engine must be built reloadable: asked for directly
    /// or implied by the adapt controller (which hot-swaps maps).
    pub fn wants_reload(&self) -> bool {
        self.reloadable || self.adapt_dir.is_some()
    }

    /// Validate the whole config without building anything — every
    /// error `EngineBuilder::from_config` would raise from the config
    /// fields alone, raised eagerly.
    pub fn validate(&self) -> Result<()> {
        let precision = self.precision()?;
        let quant = self.quant_spec()?;
        if matches!(precision, PrecisionSource::Reference)
            && !matches!(quant.quantizer, Quantizer::Rtn)
        {
            bail!(
                "quantizer `{}` only applies to a quantized deployment — \
                 set `packed`, `map`, or an allocation field \
                 (metric/granularity/palette/budget)",
                self.quantizer
            );
        }
        if self.resident_bytes.is_some()
            && self.weight_form()? != WeightForm::Packed
        {
            bail!(
                "`resident_bytes` bounds the packed expert store — it \
                 requires a packed deployment (set `packed`)"
            );
        }
        if self.store_path.is_some() && self.resident_bytes.is_none() {
            bail!(
                "`store_path` places the tiered store's artifact — it \
                 only applies with `resident_bytes`"
            );
        }
        if self.trace_sample == 0 {
            bail!("`trace_sample` keeps 1 in N traces — N must be ≥ 1");
        }
        if self.wants_reload() && self.weight_form()? != WeightForm::Packed
        {
            bail!(
                "`reloadable`/`adapt_dir` hot-swap the packed expert \
                 store — they require a packed deployment (set `packed`)"
            );
        }
        if self.adapt_interval_secs == 0 {
            bail!("`adapt_interval_secs` must be ≥ 1");
        }
        if self.quality_sample > 0
            && self.weight_form()? != WeightForm::Packed
        {
            bail!(
                "`quality_sample` shadow-probes against the retained \
                 dense reference — it requires a packed deployment \
                 (set `packed`)"
            );
        }
        if let Some(p99) = self.slo_p99_ms {
            if !p99.is_finite() || p99 <= 0.0 {
                bail!("`slo_p99_ms` must be a positive objective");
            }
        }
        if let Some(r) = self.slo_max_reject {
            if !r.is_finite() || !(0.0..=1.0).contains(&r) {
                bail!("`slo_max_reject` is a rate — it must be in 0..=1");
            }
        }
        if let Some(a) = self.slo_min_agreement {
            if !a.is_finite() || !(0.0..=1.0).contains(&a) {
                bail!(
                    "`slo_min_agreement` is a share — it must be in 0..=1"
                );
            }
            if self.quality_sample == 0 {
                bail!(
                    "`slo_min_agreement` grades shadow-probe top-1 \
                     agreement — it needs `quality_sample` ≥ 1"
                );
            }
        }
        self.weight_form()?;
        quant.validate()?;
        Ok(())
    }

    // --- jsonx (de)serialization -------------------------------------

    /// Serialize every field (including defaults) in fixed key order —
    /// the round-trip is byte-stable, so saved configs diff cleanly.
    pub fn to_json(&self) -> Json {
        fn opt_str(v: &Option<String>) -> Json {
            v.as_ref().map_or(Json::Null, |s| Json::Str(s.clone()))
        }
        Json::Obj(vec![
            ("model".into(), Json::Str(self.model.clone())),
            ("seed".into(), Json::Num(self.seed as f64)),
            ("packed".into(), Json::Bool(self.packed)),
            (
                "map".into(),
                self.map.as_ref().map_or(Json::Null, |p| {
                    Json::Str(p.display().to_string())
                }),
            ),
            ("quantizer".into(), Json::Str(self.quantizer.clone())),
            ("damp".into(), Json::Num(self.damp)),
            ("alpha".into(), Json::Num(self.alpha)),
            (
                "calib_batches".into(),
                Json::Num(self.calib_batches as f64),
            ),
            ("calib_rows".into(), Json::Num(self.calib_rows as f64)),
            ("metric".into(), opt_str(&self.metric)),
            ("granularity".into(), opt_str(&self.granularity)),
            (
                "palette".into(),
                self.palette.as_ref().map_or(Json::Null, |p| {
                    Json::Arr(
                        p.iter().map(|&b| Json::Num(b as f64)).collect(),
                    )
                }),
            ),
            (
                "budget".into(),
                self.budget.map_or(Json::Null, Json::Num),
            ),
            (
                "hutchinson_samples".into(),
                Json::Num(self.hutchinson_samples as f64),
            ),
            (
                "closed_form_hessian".into(),
                Json::Bool(self.closed_form_hessian),
            ),
            ("workers".into(), Json::Num(self.workers as f64)),
            ("queue_depth".into(), Json::Num(self.queue_depth as f64)),
            ("linger_ms".into(), Json::Num(self.linger_ms as f64)),
            (
                "trace_buffer".into(),
                Json::Num(self.trace_buffer as f64),
            ),
            (
                "trace_sample".into(),
                Json::Num(self.trace_sample as f64),
            ),
            (
                "resident_bytes".into(),
                self.resident_bytes
                    .map_or(Json::Null, |b| Json::Num(b as f64)),
            ),
            (
                "store_path".into(),
                self.store_path.as_ref().map_or(Json::Null, |p| {
                    Json::Str(p.display().to_string())
                }),
            ),
            ("prefetch".into(), Json::Bool(self.prefetch)),
            ("listen".into(), opt_str(&self.listen)),
            ("reloadable".into(), Json::Bool(self.reloadable)),
            (
                "adapt_dir".into(),
                self.adapt_dir.as_ref().map_or(Json::Null, |p| {
                    Json::Str(p.display().to_string())
                }),
            ),
            (
                "adapt_interval_secs".into(),
                Json::Num(self.adapt_interval_secs as f64),
            ),
            (
                "quality_sample".into(),
                Json::Num(self.quality_sample as f64),
            ),
            (
                "slo_p99_ms".into(),
                self.slo_p99_ms.map_or(Json::Null, Json::Num),
            ),
            (
                "slo_max_reject".into(),
                self.slo_max_reject.map_or(Json::Null, Json::Num),
            ),
            (
                "slo_min_agreement".into(),
                self.slo_min_agreement.map_or(Json::Null, Json::Num),
            ),
        ])
    }

    /// Deserialize: missing keys take their defaults (partial configs
    /// are valid), unknown keys fail typed (the typo guard).
    pub fn from_json(j: &Json) -> Result<ServeConfig> {
        const KNOWN: [&str; 31] = [
            "model",
            "seed",
            "packed",
            "map",
            "quantizer",
            "damp",
            "alpha",
            "calib_batches",
            "calib_rows",
            "metric",
            "granularity",
            "palette",
            "budget",
            "hutchinson_samples",
            "closed_form_hessian",
            "workers",
            "queue_depth",
            "linger_ms",
            "trace_buffer",
            "trace_sample",
            "resident_bytes",
            "store_path",
            "prefetch",
            "listen",
            "reloadable",
            "adapt_dir",
            "adapt_interval_secs",
            "quality_sample",
            "slo_p99_ms",
            "slo_max_reject",
            "slo_min_agreement",
        ];
        for (k, _) in j.as_obj()? {
            if !KNOWN.contains(&k.as_str()) {
                bail!(
                    "unknown serve-config key `{k}` (known: {})",
                    KNOWN.join(", ")
                );
            }
        }
        let mut sc = ServeConfig::default();
        let get = |key: &str| match j.get(key) {
            Some(Json::Null) | None => None,
            Some(v) => Some(v),
        };
        if let Some(v) = get("model") {
            sc.model = v.as_str()?.to_string();
        }
        if let Some(v) = get("seed") {
            sc.seed = v.as_usize()? as u64;
        }
        if let Some(v) = get("packed") {
            sc.packed = as_bool(v)?;
        }
        if let Some(v) = get("map") {
            sc.map = Some(PathBuf::from(v.as_str()?));
        }
        if let Some(v) = get("quantizer") {
            sc.quantizer = v.as_str()?.to_string();
        }
        if let Some(v) = get("damp") {
            sc.damp = v.as_f64()?;
        }
        if let Some(v) = get("alpha") {
            sc.alpha = v.as_f64()?;
        }
        if let Some(v) = get("calib_batches") {
            sc.calib_batches = v.as_usize()?;
        }
        if let Some(v) = get("calib_rows") {
            sc.calib_rows = v.as_usize()?;
        }
        if let Some(v) = get("metric") {
            sc.metric = Some(v.as_str()?.to_string());
        }
        if let Some(v) = get("granularity") {
            sc.granularity = Some(v.as_str()?.to_string());
        }
        if let Some(v) = get("palette") {
            let widths = v
                .as_arr()?
                .iter()
                .map(|b| {
                    let b = b.as_usize()?;
                    if b > u8::MAX as usize {
                        bail!("palette width {b} out of range");
                    }
                    Ok(b as u8)
                })
                .collect::<Result<Vec<u8>>>()?;
            sc.palette = Some(widths);
        }
        if let Some(v) = get("budget") {
            sc.budget = Some(v.as_f64()?);
        }
        if let Some(v) = get("hutchinson_samples") {
            sc.hutchinson_samples = v.as_usize()?;
        }
        if let Some(v) = get("closed_form_hessian") {
            sc.closed_form_hessian = as_bool(v)?;
        }
        if let Some(v) = get("workers") {
            sc.workers = v.as_usize()?;
        }
        if let Some(v) = get("queue_depth") {
            sc.queue_depth = v.as_usize()?;
        }
        if let Some(v) = get("linger_ms") {
            sc.linger_ms = v.as_usize()? as u64;
        }
        if let Some(v) = get("trace_buffer") {
            sc.trace_buffer = v.as_usize()?;
        }
        if let Some(v) = get("trace_sample") {
            sc.trace_sample = v.as_usize()?;
        }
        if let Some(v) = get("resident_bytes") {
            sc.resident_bytes = Some(v.as_usize()?);
        }
        if let Some(v) = get("store_path") {
            sc.store_path = Some(PathBuf::from(v.as_str()?));
        }
        if let Some(v) = get("prefetch") {
            sc.prefetch = as_bool(v)?;
        }
        if let Some(v) = get("listen") {
            sc.listen = Some(v.as_str()?.to_string());
        }
        if let Some(v) = get("reloadable") {
            sc.reloadable = as_bool(v)?;
        }
        if let Some(v) = get("adapt_dir") {
            sc.adapt_dir = Some(PathBuf::from(v.as_str()?));
        }
        if let Some(v) = get("adapt_interval_secs") {
            sc.adapt_interval_secs = v.as_usize()? as u64;
        }
        if let Some(v) = get("quality_sample") {
            sc.quality_sample = v.as_usize()?;
        }
        if let Some(v) = get("slo_p99_ms") {
            sc.slo_p99_ms = Some(v.as_f64()?);
        }
        if let Some(v) = get("slo_max_reject") {
            sc.slo_max_reject = Some(v.as_f64()?);
        }
        if let Some(v) = get("slo_min_agreement") {
            sc.slo_min_agreement = Some(v.as_f64()?);
        }
        Ok(sc)
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        std::fs::write(path, self.to_json().to_string())
            .map_err(|e| anyhow!("writing {}: {e}", path.display()))
    }

    pub fn load(path: &Path) -> Result<ServeConfig> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow!("reading {}: {e}", path.display()))?;
        let j = Json::parse(&text)
            .map_err(|e| anyhow!("parsing {}: {e}", path.display()))?;
        ServeConfig::from_json(&j)
            .map_err(|e| anyhow!("in {}: {e}", path.display()))
    }

    // --- CLI merge ----------------------------------------------------

    /// Overlay present CLI flags onto this config — the "flags override
    /// file" contract of `mopeq serve --config`. Flag-level guards
    /// (quantizer-specific knobs on the wrong quantizer) fire here,
    /// after the merge, so `--damp` over a `"quantizer": "gptq"` file
    /// is accepted while `--damp` over an RTN deployment still fails.
    pub fn apply_flags(&mut self, args: &Args) -> Result<()> {
        if let Some(m) = args.flags.get("model") {
            self.model = m.clone();
        }
        self.seed = args.u64_flag("seed", self.seed)?;
        if args.switch("packed") {
            self.packed = true;
        }
        if let Some(m) = args.flags.get("map") {
            self.map = Some(PathBuf::from(m));
        }
        if let Some(q) = args.flags.get("quantizer") {
            self.quantizer = q.clone();
        }
        self.damp = args.f64_flag("damp", self.damp)?;
        self.alpha = args.f64_flag("alpha", self.alpha)?;
        self.calib_batches =
            args.usize_flag("calib-batches", self.calib_batches)?;
        self.calib_rows = args.usize_flag("calib-rows", self.calib_rows)?;
        if let Some(m) = args.flags.get("metric") {
            self.metric = Some(m.clone());
        }
        if let Some(g) = args.flags.get("granularity") {
            self.granularity = Some(g.clone());
        }
        if let Some(csv) = args.flags.get("palette") {
            let widths = csv
                .split(',')
                .map(|s| {
                    s.trim()
                        .parse::<u8>()
                        .map_err(|_| anyhow!("--palette: bad width `{s}`"))
                })
                .collect::<Result<Vec<u8>>>()?;
            self.palette = Some(widths);
        }
        if args.flags.contains_key("budget") {
            self.budget = Some(args.f64_flag("budget", 0.0)?);
        }
        self.hutchinson_samples =
            args.usize_flag("hutchinson-samples", self.hutchinson_samples)?;
        if args.switch("closed-form-hessian") {
            self.closed_form_hessian = true;
        }
        // estimator knobs are a request for the estimator-backed metric
        // (the CLI's historical semantics) — they must never be
        // accepted-but-ignored under the default closed-form metric
        if self.metric.is_none()
            && (args.flags.contains_key("hutchinson-samples")
                || args.switch("closed-form-hessian"))
        {
            self.metric = Some("hessian".into());
        }
        self.workers = args.usize_flag("workers", self.workers)?;
        self.queue_depth = args.usize_flag("queue-depth", self.queue_depth)?;
        self.linger_ms = args.u64_flag("linger-ms", self.linger_ms)?;
        self.trace_buffer =
            args.usize_flag("trace-buffer", self.trace_buffer)?;
        self.trace_sample =
            args.usize_flag("trace-sample", self.trace_sample)?;
        if args.flags.contains_key("resident-bytes") {
            self.resident_bytes =
                Some(args.usize_flag("resident-bytes", 0)?);
        }
        if let Some(p) = args.flags.get("store-path") {
            self.store_path = Some(PathBuf::from(p));
        }
        if args.switch("no-prefetch") {
            self.prefetch = false;
        }
        if let Some(l) = args.flags.get("listen") {
            self.listen = Some(l.clone());
        }
        if args.switch("reloadable") {
            self.reloadable = true;
        }
        if let Some(d) = args.flags.get("adapt") {
            self.adapt_dir = Some(PathBuf::from(d));
        }
        self.adapt_interval_secs = args
            .u64_flag("adapt-interval-secs", self.adapt_interval_secs)?;
        self.quality_sample =
            args.usize_flag("quality-sample", self.quality_sample)?;
        if args.flags.contains_key("slo-p99-ms") {
            self.slo_p99_ms = Some(args.f64_flag("slo-p99-ms", 0.0)?);
        }
        if args.flags.contains_key("slo-max-reject") {
            self.slo_max_reject =
                Some(args.f64_flag("slo-max-reject", 0.0)?);
        }
        if args.flags.contains_key("slo-min-agreement") {
            self.slo_min_agreement =
                Some(args.f64_flag("slo-min-agreement", 0.0)?);
        }
        // quantizer-specific flags on the wrong (merged) quantizer
        if args.flags.contains_key("damp") && self.quantizer != "gptq" {
            bail!("--damp only applies to --quantizer gptq");
        }
        if args.flags.contains_key("alpha") && self.quantizer != "awq" {
            bail!("--alpha only applies to --quantizer awq");
        }
        // a map file IS the allocation — reject a flag-level mix even
        // when the map came from the file and the metric from a flag
        if self.map.is_some() && self.has_alloc() {
            bail!(
                "--map loads a finished allocation; drop --metric/\
                 --granularity/--palette/--budget (or drop --map to \
                 allocate from those flags)"
            );
        }
        Ok(())
    }
}

fn as_bool(j: &Json) -> Result<bool> {
    match j {
        Json::Bool(b) => Ok(*b),
        _ => bail!("not a bool: {j:?}"),
    }
}

impl EngineBuilder {
    /// One deployment decision tree for every consumer: the CLI's
    /// `mopeq serve`, the network front-end, and the tests all turn a
    /// [`ServeConfig`] into a builder here, so "the same config" can
    /// never mean two different engines. Weights are threaded
    /// separately ([`EngineBuilder::weights`]) — the config describes
    /// the deployment shape, not the checkpoint.
    pub fn from_config(sc: &ServeConfig) -> Result<EngineBuilder> {
        sc.validate()?;
        let mut b = Engine::builder(&sc.model)
            .seed(sc.seed)
            .weight_form(sc.weight_form()?)
            .precision(sc.precision()?)
            .quantizer(sc.quant_spec()?)
            .workers(sc.workers)
            .queue_depth(sc.queue_depth)
            .batch_policy(BatchPolicy {
                max_linger: Duration::from_millis(sc.linger_ms),
            })
            .trace_buffer(sc.trace_buffer)
            .trace_sample(sc.trace_sample)
            .prefetch(sc.prefetch)
            // quality probes re-execute on the retained dense weights,
            // which is exactly what the reload path keeps around
            .reloadable(sc.wants_reload() || sc.quality_sample > 0)
            .quality_sample(sc.quality_sample)
            .slo(crate::obs::health::SloConfig {
                p99_ms: sc.slo_p99_ms,
                max_reject: sc.slo_max_reject,
                min_agreement: sc.slo_min_agreement,
            });
        if let Some(cap) = sc.resident_bytes {
            b = b.resident_bytes(cap);
        }
        if let Some(p) = &sc.store_path {
            b = b.store_path(p.clone());
        }
        Ok(b)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn json_round_trip_is_byte_stable() {
        let mut sc = ServeConfig {
            model: "molmoe".into(),
            seed: 9,
            packed: true,
            quantizer: "gptq".into(),
            metric: Some("hybrid".into()),
            granularity: Some("layer".into()),
            palette: Some(vec![2, 4]),
            budget: Some(3.25),
            trace_sample: 8,
            resident_bytes: Some(262_144),
            store_path: Some(PathBuf::from("stores/a.bin")),
            prefetch: false,
            listen: Some("127.0.0.1:0".into()),
            reloadable: true,
            adapt_dir: Some(PathBuf::from("frontier")),
            adapt_interval_secs: 3,
            ..ServeConfig::default()
        };
        for cfg in [sc.clone(), ServeConfig::default(), {
            sc.map = Some(PathBuf::from("maps/best.json"));
            sc.metric = None;
            sc.granularity = None;
            sc.palette = None;
            sc.budget = None;
            sc
        }] {
            let wire = cfg.to_json().to_string();
            let back =
                ServeConfig::from_json(&Json::parse(&wire).unwrap()).unwrap();
            assert_eq!(back, cfg);
            assert_eq!(back.to_json().to_string(), wire, "byte-stable");
        }
    }

    #[test]
    fn partial_configs_default_and_typos_fail_typed() {
        let j = Json::parse(r#"{"model": "molmoe", "packed": true}"#).unwrap();
        let sc = ServeConfig::from_json(&j).unwrap();
        assert_eq!(sc.model, "molmoe");
        assert!(sc.packed);
        assert_eq!(sc.workers, 1);
        assert_eq!(sc.queue_depth, 128);

        let typo = Json::parse(r#"{"worker": 4}"#).unwrap();
        let err = ServeConfig::from_json(&typo).unwrap_err();
        assert!(err.to_string().contains("worker"), "{err}");
    }

    #[test]
    fn save_load_round_trips_through_a_file() {
        let dir = std::env::temp_dir().join("mopeq_serve_config_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("serve.json");
        let sc = ServeConfig {
            packed: true,
            workers: 2,
            budget: Some(3.0),
            ..ServeConfig::default()
        };
        sc.save(&path).unwrap();
        assert_eq!(ServeConfig::load(&path).unwrap(), sc);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn flags_override_file_values() {
        let mut sc = ServeConfig {
            workers: 2,
            queue_depth: 64,
            packed: true,
            ..ServeConfig::default()
        };
        let args = crate::cli::parse(&argv(&[
            "serve", "--workers", "4", "--linger-ms", "7",
            "--trace-buffer", "32", "--listen", "127.0.0.1:0",
        ]));
        sc.apply_flags(&args).unwrap();
        assert_eq!(sc.workers, 4, "flag overrides file");
        assert_eq!(sc.queue_depth, 64, "absent flag keeps file value");
        assert_eq!(sc.linger_ms, 7);
        assert_eq!(sc.trace_buffer, 32);
        assert!(sc.packed);
        assert_eq!(sc.listen.as_deref(), Some("127.0.0.1:0"));
    }

    #[test]
    fn store_knobs_merge_and_guard() {
        // flags overlay the file values
        let mut sc = ServeConfig { packed: true, ..ServeConfig::default() };
        let args = crate::cli::parse(&argv(&[
            "serve", "--resident-bytes", "262144", "--store-path",
            "s.bin", "--no-prefetch", "--trace-sample", "10",
        ]));
        sc.apply_flags(&args).unwrap();
        assert_eq!(sc.resident_bytes, Some(262_144));
        assert_eq!(sc.store_path.as_deref(), Some(Path::new("s.bin")));
        assert!(!sc.prefetch);
        assert_eq!(sc.trace_sample, 10);
        sc.validate().unwrap();
        // resident_bytes without packed is a typed error
        let sc = ServeConfig {
            resident_bytes: Some(1 << 20),
            ..ServeConfig::default()
        };
        let err = sc.validate().unwrap_err();
        assert!(err.to_string().contains("packed"), "{err}");
        // store_path without resident_bytes is a typed error
        let sc = ServeConfig {
            packed: true,
            store_path: Some(PathBuf::from("s.bin")),
            ..ServeConfig::default()
        };
        let err = sc.validate().unwrap_err();
        assert!(err.to_string().contains("resident_bytes"), "{err}");
        // trace_sample 0 is a typed error
        let sc = ServeConfig { trace_sample: 0, ..ServeConfig::default() };
        assert!(sc.validate().is_err());
    }

    #[test]
    fn adapt_knobs_merge_and_guard() {
        // flags overlay the file values, --adapt implies reloadable
        let mut sc = ServeConfig { packed: true, ..ServeConfig::default() };
        let args = crate::cli::parse(&argv(&[
            "serve", "--adapt", "frontier", "--adapt-interval-secs", "2",
        ]));
        sc.apply_flags(&args).unwrap();
        assert_eq!(sc.adapt_dir.as_deref(), Some(Path::new("frontier")));
        assert_eq!(sc.adapt_interval_secs, 2);
        assert!(!sc.reloadable, "--adapt implies, not sets, reloadable");
        assert!(sc.wants_reload());
        sc.validate().unwrap();
        // --reloadable alone also wants the reload path
        let mut sc = ServeConfig { packed: true, ..ServeConfig::default() };
        let args = crate::cli::parse(&argv(&["serve", "--reloadable"]));
        sc.apply_flags(&args).unwrap();
        assert!(sc.reloadable && sc.wants_reload());
        sc.validate().unwrap();
        // hot-swap without a packed deployment is a typed error
        let sc = ServeConfig { reloadable: true, ..ServeConfig::default() };
        let err = sc.validate().unwrap_err();
        assert!(err.to_string().contains("packed"), "{err}");
        // a zero observation interval is a typed error
        let sc = ServeConfig {
            packed: true,
            adapt_dir: Some(PathBuf::from("frontier")),
            adapt_interval_secs: 0,
            ..ServeConfig::default()
        };
        assert!(sc.validate().is_err());
    }

    #[test]
    fn quality_and_slo_knobs_merge_and_guard() {
        // flags overlay the file values
        let mut sc = ServeConfig { packed: true, ..ServeConfig::default() };
        let args = crate::cli::parse(&argv(&[
            "serve", "--quality-sample", "4", "--slo-p99-ms", "250",
            "--slo-max-reject", "0.05", "--slo-min-agreement", "0.9",
        ]));
        sc.apply_flags(&args).unwrap();
        assert_eq!(sc.quality_sample, 4);
        assert_eq!(sc.slo_p99_ms, Some(250.0));
        assert_eq!(sc.slo_max_reject, Some(0.05));
        assert_eq!(sc.slo_min_agreement, Some(0.9));
        sc.validate().unwrap();
        // probes without a packed deployment are a typed error
        let sc = ServeConfig {
            quality_sample: 4,
            ..ServeConfig::default()
        };
        let err = sc.validate().unwrap_err();
        assert!(err.to_string().contains("packed"), "{err}");
        // an agreement SLO without probes can never be graded
        let sc = ServeConfig {
            packed: true,
            slo_min_agreement: Some(0.9),
            ..ServeConfig::default()
        };
        let err = sc.validate().unwrap_err();
        assert!(err.to_string().contains("quality_sample"), "{err}");
        // rates outside 0..=1 are typed errors
        for bad in [
            ServeConfig {
                packed: true,
                slo_max_reject: Some(1.5),
                ..ServeConfig::default()
            },
            ServeConfig {
                packed: true,
                quality_sample: 2,
                slo_min_agreement: Some(-0.1),
                ..ServeConfig::default()
            },
            ServeConfig {
                packed: true,
                slo_p99_ms: Some(0.0),
                ..ServeConfig::default()
            },
        ] {
            assert!(bad.validate().is_err());
        }
        // round trip keeps the new fields byte-stable
        let sc = ServeConfig {
            packed: true,
            quality_sample: 8,
            slo_p99_ms: Some(100.0),
            slo_max_reject: Some(0.01),
            slo_min_agreement: Some(0.95),
            ..ServeConfig::default()
        };
        let wire = sc.to_json().to_string();
        let back =
            ServeConfig::from_json(&Json::parse(&wire).unwrap()).unwrap();
        assert_eq!(back, sc);
        assert_eq!(back.to_json().to_string(), wire);
    }

    #[test]
    fn estimator_knobs_select_the_estimator_metric() {
        let mut sc = ServeConfig::default();
        let args = crate::cli::parse(&argv(&[
            "serve", "--packed", "--hutchinson-samples", "4",
        ]));
        sc.apply_flags(&args).unwrap();
        assert_eq!(sc.metric.as_deref(), Some("hessian"));
        assert_eq!(
            sc.spec_metric().unwrap(),
            Metric::Hessian(Estimator::Hutchinson { samples: 4 })
        );
        // without knobs, the default stays the paper's closed form
        assert_eq!(
            ServeConfig::default().spec_metric().unwrap(),
            AllocPolicy::default().metric
        );
    }

    #[test]
    fn decision_tree_matches_the_serve_cli() {
        // bare default = fp16 reference
        let sc = ServeConfig::default();
        assert!(matches!(
            sc.precision().unwrap(),
            PrecisionSource::Reference
        ));
        assert_eq!(sc.weight_form().unwrap(), WeightForm::Fp16);
        // packed = the paper allocation
        let sc = ServeConfig { packed: true, ..ServeConfig::default() };
        match sc.precision().unwrap() {
            PrecisionSource::Allocated(p) => {
                assert_eq!(p, AllocPolicy::default());
            }
            other => panic!("expected Allocated, got {other:?}"),
        }
        assert_eq!(sc.weight_form().unwrap(), WeightForm::Packed);
        // allocation field without packed = qdq→f32
        let sc = ServeConfig {
            budget: Some(3.0),
            ..ServeConfig::default()
        };
        assert!(matches!(
            sc.precision().unwrap(),
            PrecisionSource::Allocated(_)
        ));
        assert_eq!(sc.weight_form().unwrap(), WeightForm::DequantizedF32);
        // map is exclusive with allocation fields
        let sc = ServeConfig {
            map: Some(PathBuf::from("m.json")),
            budget: Some(3.0),
            ..ServeConfig::default()
        };
        assert!(sc.precision().is_err());
        // quantizer needs a quantizing deployment
        let sc = ServeConfig {
            quantizer: "gptq".into(),
            ..ServeConfig::default()
        };
        let err = sc.validate().unwrap_err();
        assert!(err.to_string().contains("quantized deployment"), "{err}");
        // quantizer typo is a typo error
        let sc = ServeConfig {
            packed: true,
            quantizer: "gtpq".into(),
            ..ServeConfig::default()
        };
        assert!(sc.validate().unwrap_err().to_string().contains("gtpq"));
    }

    #[test]
    fn flag_guards_fire_after_the_merge() {
        // --damp over a gptq config file is fine
        let mut sc = ServeConfig {
            packed: true,
            quantizer: "gptq".into(),
            ..ServeConfig::default()
        };
        let args =
            crate::cli::parse(&argv(&["serve", "--damp", "0.05"]));
        sc.apply_flags(&args).unwrap();
        assert_eq!(sc.damp, 0.05);
        // --damp over an RTN deployment still fails
        let mut sc = ServeConfig { packed: true, ..ServeConfig::default() };
        let args =
            crate::cli::parse(&argv(&["serve", "--damp", "0.05"]));
        assert!(sc.apply_flags(&args).is_err());
        // map from file + metric from flag is the same conflict as
        // --map + --metric
        let mut sc = ServeConfig {
            map: Some(PathBuf::from("m.json")),
            ..ServeConfig::default()
        };
        let args =
            crate::cli::parse(&argv(&["serve", "--metric", "hessian"]));
        assert!(sc.apply_flags(&args).is_err());
    }

    #[test]
    fn from_config_builds_the_paper_packed_engine() {
        let sc = ServeConfig {
            packed: true,
            workers: 2,
            queue_depth: 16,
            ..ServeConfig::default()
        };
        let engine = EngineBuilder::from_config(&sc)
            .unwrap()
            .build()
            .expect("from_config engine build");
        // identical to the hand-composed paper deployment
        let manual = Engine::builder("dsvl2_tiny")
            .weight_form(WeightForm::Packed)
            .precision(PrecisionSource::mopeq())
            .build()
            .unwrap();
        assert_eq!(
            engine.precision_map().unwrap().bits,
            manual.precision_map().unwrap().bits,
            "from_config and the manual builder must resolve the same map"
        );
        engine.shutdown().unwrap();
        manual.shutdown().unwrap();
    }
}
