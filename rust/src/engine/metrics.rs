//! Live engine telemetry: lock-light counters + per-worker logs that a
//! [`MetricsSnapshot`](crate::engine::MetricsSnapshot) can be cut from
//! **while serving** — queue depth, admission rejections, per-worker
//! batch-fill histograms and latency percentiles, and the measured
//! resident weight bytes. Shutdown stats are just the final snapshot;
//! there is no separate end-of-life accounting path that could disagree
//! with the live one.

use crate::coordinator::executor::ResidentReport;
use crate::jsonx::Json;
use crate::obs::trace::TraceSummary;
use crate::store::StoreSnapshot;
use anyhow::Result;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Upper bounds (seconds) of the request-latency histogram buckets —
/// the Prometheus `mopeq_request_duration_seconds_bucket` `le` ladder.
/// Counts are **cumulative** per the exposition format (each bucket
/// counts every request at or under its bound; `+Inf` is the request
/// total and is not stored, it's appended at render).
pub const LATENCY_BUCKETS: [f64; 12] = [
    0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25, 0.5,
    1.0, 2.5,
];

/// Point-in-time view of a running (or just-shut-down) engine.
#[derive(Clone, Debug, Default)]
pub struct MetricsSnapshot {
    /// jobs currently admitted but not yet executed
    pub queue_depth: usize,
    /// submits admitted past admission control
    pub submitted: usize,
    /// requests answered (== Σ over workers of their batch fills)
    pub requests: usize,
    /// submits rejected with [`Rejected::Busy`](crate::engine::Rejected)
    pub rejected_busy: usize,
    /// admitted jobs whose per-request deadline expired before execution
    pub rejected_deadline: usize,
    /// batches executed across all workers
    pub batches: usize,
    /// mean real requests per executed batch
    pub mean_fill: f64,
    pub p50: Duration,
    pub p95: Duration,
    pub p99: Duration,
    /// answered requests per second of engine uptime
    pub throughput_rps: f64,
    pub uptime: Duration,
    /// weight bytes **one worker's** executor holds resident. Workers
    /// are replicas over Arc-shared pre-sliced arguments (dense
    /// backbone and expert slices included, not just packed words), so
    /// `resident.shared_bytes == resident.backbone_bytes +
    /// resident.expert_heap_bytes` for every engine deployment —
    /// asserted at build — and the per-process footprint
    /// (`resident.process_bytes(workers)`) does not multiply with the
    /// worker count.
    pub resident: ResidentReport,
    pub workers: Vec<WorkerSnapshot>,
    /// per-stage trace percentiles over the trace ring's window.
    /// `Metrics` itself cannot see the ring (it lives next to it on the
    /// engine's shared state), so [`Metrics::snapshot`] leaves this at
    /// default and the engine-level snapshot path fills it in.
    pub trace: TraceSummary,
    /// tiered expert store counters when the engine runs with a
    /// bounded resident set (`--resident-bytes`); `None` for fully
    /// resident deployments. Filled by the engine-level snapshot path
    /// like [`MetricsSnapshot::trace`].
    pub store: Option<StoreSnapshot>,
    /// cumulative request-latency histogram over [`LATENCY_BUCKETS`]
    /// (`latency_buckets[i]` = requests with latency ≤ bucket `i`'s
    /// bound; the implicit `+Inf` count is `requests`)
    pub latency_buckets: Vec<usize>,
    /// total answered-request latency (the histogram's `_sum`)
    pub latency_sum: Duration,
    /// current hot-swap weight generation (0 = the build-time weights).
    /// Filled by the engine-level snapshot path.
    pub adapt_generation: u64,
    /// completed zero-downtime map swaps
    pub adapt_swaps: u64,
    /// last routing-drift distance the adapt controller observed
    /// (max-over-layers total variation, 0 when no controller runs)
    pub adapt_last_drift: f64,
}

/// One worker's slice of the snapshot.
#[derive(Clone, Debug, Default)]
pub struct WorkerSnapshot {
    pub requests: usize,
    pub batches: usize,
    pub mean_fill: f64,
    /// `fill_hist[k-1]` = batches that executed with k real requests
    pub fill_hist: Vec<usize>,
    pub p50: Duration,
    pub p95: Duration,
    pub p99: Duration,
}

fn dur_json(d: Duration) -> Json {
    Json::Num(d.as_nanos() as f64)
}

fn dur_from(j: &Json) -> Result<Duration> {
    let ns = j.as_f64()?;
    if !ns.is_finite() || ns < 0.0 {
        anyhow::bail!("bad duration: {ns} ns");
    }
    Ok(Duration::from_nanos(ns as u64))
}

impl MetricsSnapshot {
    /// Rejections of every kind (busy + deadline).
    pub fn rejected_total(&self) -> usize {
        self.rejected_busy + self.rejected_deadline
    }

    /// Fraction of admission attempts that were rejected, in `[0, 1]`.
    /// `submitted` already counts deadline-rejected jobs (they were
    /// admitted) but not busy-rejected ones (uncounted at rejection),
    /// so attempts = submitted + rejected_busy — a busy flood can't
    /// hide behind a small `submitted`.
    pub fn reject_rate(&self) -> f64 {
        let attempts = self.submitted + self.rejected_busy;
        if attempts == 0 {
            0.0
        } else {
            self.rejected_total() as f64 / attempts as f64
        }
    }

    /// The `GET /metrics` wire body — every field, durations in
    /// nanoseconds, per-worker slices included. Key order is fixed, so
    /// the serialization is byte-stable across a
    /// [`from_json`](Self::from_json) round-trip (asserted in the unit
    /// tests; the future traffic-aware reallocation loop diffs these).
    pub fn to_json(&self) -> Json {
        Json::Obj(vec![
            ("queue_depth".into(), Json::Num(self.queue_depth as f64)),
            ("submitted".into(), Json::Num(self.submitted as f64)),
            ("requests".into(), Json::Num(self.requests as f64)),
            (
                "rejected_busy".into(),
                Json::Num(self.rejected_busy as f64),
            ),
            (
                "rejected_deadline".into(),
                Json::Num(self.rejected_deadline as f64),
            ),
            ("batches".into(), Json::Num(self.batches as f64)),
            ("mean_fill".into(), Json::Num(self.mean_fill)),
            ("p50_ns".into(), dur_json(self.p50)),
            ("p95_ns".into(), dur_json(self.p95)),
            ("p99_ns".into(), dur_json(self.p99)),
            ("throughput_rps".into(), Json::Num(self.throughput_rps)),
            ("uptime_ns".into(), dur_json(self.uptime)),
            ("resident".into(), resident_json(&self.resident)),
            (
                "workers".into(),
                Json::Arr(self.workers.iter().map(|w| w.to_json()).collect()),
            ),
            ("trace".into(), self.trace.to_json()),
            (
                "store".into(),
                match &self.store {
                    Some(s) => s.to_json(),
                    None => Json::Null,
                },
            ),
            (
                "latency_buckets".into(),
                Json::Arr(
                    self.latency_buckets
                        .iter()
                        .map(|&n| Json::Num(n as f64))
                        .collect(),
                ),
            ),
            ("latency_sum_ns".into(), dur_json(self.latency_sum)),
            (
                "adapt_generation".into(),
                Json::Num(self.adapt_generation as f64),
            ),
            ("adapt_swaps".into(), Json::Num(self.adapt_swaps as f64)),
            (
                "adapt_last_drift".into(),
                Json::Num(self.adapt_last_drift),
            ),
        ])
    }

    pub fn from_json(j: &Json) -> Result<MetricsSnapshot> {
        Ok(MetricsSnapshot {
            queue_depth: j.req("queue_depth")?.as_usize()?,
            submitted: j.req("submitted")?.as_usize()?,
            requests: j.req("requests")?.as_usize()?,
            rejected_busy: j.req("rejected_busy")?.as_usize()?,
            rejected_deadline: j.req("rejected_deadline")?.as_usize()?,
            batches: j.req("batches")?.as_usize()?,
            mean_fill: j.req("mean_fill")?.as_f64()?,
            p50: dur_from(j.req("p50_ns")?)?,
            p95: dur_from(j.req("p95_ns")?)?,
            p99: dur_from(j.req("p99_ns")?)?,
            throughput_rps: j.req("throughput_rps")?.as_f64()?,
            uptime: dur_from(j.req("uptime_ns")?)?,
            resident: resident_from_json(j.req("resident")?)?,
            workers: j
                .req("workers")?
                .as_arr()?
                .iter()
                .map(WorkerSnapshot::from_json)
                .collect::<Result<_>>()?,
            trace: TraceSummary::from_json(j.req("trace")?)?,
            store: match j.req("store")? {
                Json::Null => None,
                s => Some(StoreSnapshot::from_json(s)?),
            },
            latency_buckets: j
                .req("latency_buckets")?
                .as_arr()?
                .iter()
                .map(|v| v.as_usize())
                .collect::<Result<_>>()?,
            latency_sum: dur_from(j.req("latency_sum_ns")?)?,
            adapt_generation: j.req("adapt_generation")?.as_usize()? as u64,
            adapt_swaps: j.req("adapt_swaps")?.as_usize()? as u64,
            adapt_last_drift: j.req("adapt_last_drift")?.as_f64()?,
        })
    }
}

impl WorkerSnapshot {
    pub fn to_json(&self) -> Json {
        Json::Obj(vec![
            ("requests".into(), Json::Num(self.requests as f64)),
            ("batches".into(), Json::Num(self.batches as f64)),
            ("mean_fill".into(), Json::Num(self.mean_fill)),
            (
                "fill_hist".into(),
                Json::Arr(
                    self.fill_hist
                        .iter()
                        .map(|&n| Json::Num(n as f64))
                        .collect(),
                ),
            ),
            ("p50_ns".into(), dur_json(self.p50)),
            ("p95_ns".into(), dur_json(self.p95)),
            ("p99_ns".into(), dur_json(self.p99)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<WorkerSnapshot> {
        Ok(WorkerSnapshot {
            requests: j.req("requests")?.as_usize()?,
            batches: j.req("batches")?.as_usize()?,
            mean_fill: j.req("mean_fill")?.as_f64()?,
            fill_hist: j
                .req("fill_hist")?
                .as_arr()?
                .iter()
                .map(|v| v.as_usize())
                .collect::<Result<_>>()?,
            p50: dur_from(j.req("p50_ns")?)?,
            p95: dur_from(j.req("p95_ns")?)?,
            p99: dur_from(j.req("p99_ns")?)?,
        })
    }
}

fn resident_json(r: &ResidentReport) -> Json {
    Json::Obj(vec![
        ("backbone_bytes".into(), Json::Num(r.backbone_bytes as f64)),
        (
            "expert_accounted_bytes".into(),
            Json::Num(r.expert_accounted_bytes as f64),
        ),
        (
            "expert_heap_bytes".into(),
            Json::Num(r.expert_heap_bytes as f64),
        ),
        (
            "dense_expert_tensors".into(),
            Json::Num(r.dense_expert_tensors as f64),
        ),
        ("shared_bytes".into(), Json::Num(r.shared_bytes as f64)),
    ])
}

fn resident_from_json(j: &Json) -> Result<ResidentReport> {
    Ok(ResidentReport {
        backbone_bytes: j.req("backbone_bytes")?.as_usize()?,
        expert_accounted_bytes: j.req("expert_accounted_bytes")?.as_usize()?,
        expert_heap_bytes: j.req("expert_heap_bytes")?.as_usize()?,
        dense_expert_tensors: j.req("dense_expert_tensors")?.as_usize()?,
        shared_bytes: j.req("shared_bytes")?.as_usize()?,
    })
}

/// Per-worker mutable log (one `Mutex` each — workers never contend
/// with each other, only with a snapshot reader).
#[derive(Default)]
struct WorkerLog {
    batches: usize,
    fills: usize,
    fill_hist: Vec<usize>,
    latencies: Vec<Duration>,
}

pub(crate) struct Metrics {
    started: Mutex<Instant>,
    submitted: AtomicUsize,
    rejected_busy: AtomicUsize,
    rejected_deadline: AtomicUsize,
    resident: Mutex<Option<ResidentReport>>,
    workers: Vec<Mutex<WorkerLog>>,
}

impl Metrics {
    pub fn new(workers: usize) -> Metrics {
        Metrics {
            started: Mutex::new(Instant::now()),
            submitted: AtomicUsize::new(0),
            rejected_busy: AtomicUsize::new(0),
            rejected_deadline: AtomicUsize::new(0),
            resident: Mutex::new(None),
            workers: (0..workers).map(|_| Mutex::new(WorkerLog::default())).collect(),
        }
    }

    /// Restart the uptime clock — called once every worker has warmed,
    /// so `throughput_rps` measures pure serving time, never session
    /// open / executor compile cost (the worker-count sweep would
    /// otherwise be biased: each added replica adds warmup).
    pub fn mark_started(&self) {
        *self.started.lock().unwrap() = Instant::now();
    }

    /// Count an admission *attempt* — called before the queue push so a
    /// concurrent snapshot can never observe `requests > submitted`;
    /// a rejected push takes it back with
    /// [`uncount_submitted`](Self::uncount_submitted).
    pub fn count_submitted(&self) {
        self.submitted.fetch_add(1, Ordering::Relaxed);
    }

    pub fn uncount_submitted(&self) {
        self.submitted.fetch_sub(1, Ordering::Relaxed);
    }

    pub fn count_busy(&self) {
        self.rejected_busy.fetch_add(1, Ordering::Relaxed);
    }

    pub fn count_deadline(&self) {
        self.rejected_deadline.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one worker's measured residency (workers are replicas —
    /// first report wins, the rest are identical by construction).
    pub fn set_resident(&self, r: ResidentReport) {
        let mut slot = self.resident.lock().unwrap();
        slot.get_or_insert(r);
    }

    /// Record one executed batch: its real occupancy and the end-to-end
    /// latency of every request it answered.
    pub fn record_batch(&self, worker: usize, fill: usize, latencies: &[Duration]) {
        let mut log = self.workers[worker].lock().unwrap();
        log.batches += 1;
        log.fills += fill;
        if log.fill_hist.len() < fill {
            log.fill_hist.resize(fill, 0);
        }
        log.fill_hist[fill - 1] += 1;
        log.latencies.extend_from_slice(latencies);
    }

    pub fn snapshot(&self, queue_depth: usize) -> MetricsSnapshot {
        let mut workers = Vec::with_capacity(self.workers.len());
        let mut all: Vec<Duration> = Vec::new();
        let (mut batches, mut requests) = (0usize, 0usize);
        for log in &self.workers {
            let log = log.lock().unwrap();
            let mut lat = log.latencies.clone();
            lat.sort();
            workers.push(WorkerSnapshot {
                requests: log.fills,
                batches: log.batches,
                mean_fill: mean_fill(log.fills, log.batches),
                fill_hist: log.fill_hist.clone(),
                p50: percentile(&lat, 0.50),
                p95: percentile(&lat, 0.95),
                p99: percentile(&lat, 0.99),
            });
            batches += log.batches;
            requests += log.fills;
            all.extend_from_slice(&lat);
        }
        all.sort();
        // cumulative `le` buckets over the fixed ladder, plus the sum —
        // everything a real Prometheus histogram family needs
        let latency_buckets = LATENCY_BUCKETS
            .iter()
            .map(|&le| {
                all.iter().filter(|d| d.as_secs_f64() <= le).count()
            })
            .collect();
        let latency_sum = all.iter().sum();
        let uptime = self.started.lock().unwrap().elapsed();
        MetricsSnapshot {
            queue_depth,
            submitted: self.submitted.load(Ordering::Relaxed),
            requests,
            rejected_busy: self.rejected_busy.load(Ordering::Relaxed),
            rejected_deadline: self.rejected_deadline.load(Ordering::Relaxed),
            batches,
            mean_fill: mean_fill(requests, batches),
            p50: percentile(&all, 0.50),
            p95: percentile(&all, 0.95),
            p99: percentile(&all, 0.99),
            throughput_rps: requests as f64 / uptime.as_secs_f64().max(1e-9),
            uptime,
            resident: self.resident.lock().unwrap().unwrap_or_default(),
            workers,
            trace: TraceSummary::default(),
            store: None,
            latency_buckets,
            latency_sum,
            adapt_generation: 0,
            adapt_swaps: 0,
            adapt_last_drift: 0.0,
        }
    }
}

fn mean_fill(fills: usize, batches: usize) -> f64 {
    if batches == 0 {
        0.0
    } else {
        fills as f64 / batches as f64
    }
}

fn percentile(sorted: &[Duration], p: f64) -> Duration {
    if sorted.is_empty() {
        Duration::ZERO
    } else {
        sorted[((sorted.len() as f64 * p) as usize).min(sorted.len() - 1)]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_aggregates_and_stays_self_consistent() {
        let m = Metrics::new(2);
        for _ in 0..5 {
            m.count_submitted();
        }
        m.count_busy();
        m.count_deadline();
        let ms = Duration::from_millis(1);
        m.record_batch(0, 3, &[ms, 2 * ms, 3 * ms]);
        m.record_batch(1, 1, &[4 * ms]);
        let s = m.snapshot(7);
        assert_eq!(s.queue_depth, 7);
        assert_eq!(s.submitted, 5);
        assert_eq!(s.rejected_busy, 1);
        assert_eq!(s.rejected_deadline, 1);
        assert_eq!(s.batches, 2);
        assert_eq!(s.requests, 4);
        let per_worker: usize = s.workers.iter().map(|w| w.requests).sum();
        assert_eq!(s.requests, per_worker, "requests == Σ worker fills");
        assert_eq!(s.workers[0].fill_hist, vec![0, 0, 1]);
        assert_eq!(s.workers[1].fill_hist, vec![1]);
        assert!((s.mean_fill - 2.0).abs() < 1e-12);
        assert!(s.p50 <= s.p95 && s.p95 <= s.p99);
        assert_eq!(s.p99, 4 * ms);
        for w in &s.workers {
            assert!(w.p50 <= w.p95 && w.p95 <= w.p99);
        }
        assert_eq!(s.workers[0].p95, 3 * ms);
        // the latency histogram is cumulative over the fixed ladder and
        // tops out at the request count (the +Inf bucket)
        assert_eq!(s.latency_buckets.len(), LATENCY_BUCKETS.len());
        assert!(s.latency_buckets.windows(2).all(|w| w[0] <= w[1]));
        assert_eq!(*s.latency_buckets.last().unwrap(), s.requests);
        // 1 ms ≤ all four latencies ≤ 4 ms: nothing under the 0.5 ms
        // bucket, everything at or under the 5 ms bucket
        assert_eq!(s.latency_buckets[0], 0);
        assert_eq!(s.latency_buckets[3], 4);
        assert_eq!(s.latency_sum, 10 * ms);
    }

    #[test]
    fn empty_engine_snapshot_is_zeroed_not_nan() {
        let s = Metrics::new(1).snapshot(0);
        assert_eq!(s.requests, 0);
        assert_eq!(s.mean_fill, 0.0);
        assert_eq!(s.p99, Duration::ZERO);
        assert_eq!(s.rejected_total(), 0);
        assert_eq!(s.reject_rate(), 0.0);
    }

    #[test]
    fn reject_rate_counts_busy_attempts_without_double_counting() {
        let m = Metrics::new(1);
        // 4 admitted (one of which later misses its deadline) + 1
        // busy-rejected push that was uncounted = 5 attempts total
        for _ in 0..5 {
            m.count_submitted();
        }
        m.uncount_submitted();
        m.count_busy();
        m.count_deadline();
        let s = m.snapshot(0);
        assert_eq!(s.submitted, 4);
        assert_eq!(s.rejected_total(), 2);
        assert!((s.reject_rate() - 2.0 / 5.0).abs() < 1e-12);
    }

    /// A realistic populated snapshot (odd fills, non-integer mean_fill
    /// and rps, empty + ragged fill histograms, non-zero residency).
    fn busy_snapshot() -> MetricsSnapshot {
        let m = Metrics::new(3);
        for _ in 0..9 {
            m.count_submitted();
        }
        m.count_busy();
        m.count_busy();
        m.count_deadline();
        m.set_resident(ResidentReport {
            backbone_bytes: 123_456,
            expert_accounted_bytes: 7_890,
            expert_heap_bytes: 8_000,
            dense_expert_tensors: 0,
            shared_bytes: 131_456,
        });
        let us = Duration::from_micros(1);
        m.record_batch(0, 3, &[137 * us, 21 * us, 999 * us]);
        m.record_batch(0, 1, &[5 * us]);
        m.record_batch(2, 4, &[us, 2 * us, 3 * us, 4 * us]);
        m.snapshot(2)
    }

    #[test]
    fn snapshot_json_round_trip_is_byte_stable() {
        // to_json → string → parse → from_json → to_json → string must
        // reproduce the exact bytes: this is what `/metrics` returns and
        // what the traffic-aware reallocation loop will diff
        let mut tiered = busy_snapshot();
        tiered.store = Some(StoreSnapshot {
            capacity_bytes: 262_144,
            resident_bytes: 258_048,
            resident_experts: 60,
            total_experts: 704,
            artifact_bytes: 2_700_000,
            prefetch_enabled: true,
            hits: 900,
            misses: 100,
            prefetch_hits: 400,
            prefetched: 450,
            evictions: 80,
            bytes_paged: 460_800,
        });
        tiered.adapt_generation = 2;
        tiered.adapt_swaps = 2;
        tiered.adapt_last_drift = 0.375;
        for s in [busy_snapshot(), tiered, Metrics::new(1).snapshot(0)] {
            let wire = s.to_json().to_string();
            let parsed = crate::jsonx::Json::parse(&wire).unwrap();
            let back = MetricsSnapshot::from_json(&parsed).unwrap();
            assert_eq!(
                back.to_json().to_string(),
                wire,
                "metrics wire body must round-trip byte-for-byte"
            );
            // spot-check typed equality on the load-bearing fields
            assert_eq!(back.requests, s.requests);
            assert_eq!(back.submitted, s.submitted);
            assert_eq!(back.rejected_busy, s.rejected_busy);
            assert_eq!(back.p99, s.p99);
            assert_eq!(back.mean_fill, s.mean_fill);
            assert_eq!(back.throughput_rps, s.throughput_rps);
            assert_eq!(back.workers.len(), s.workers.len());
            for (a, b) in back.workers.iter().zip(&s.workers) {
                assert_eq!(a.fill_hist, b.fill_hist);
                assert_eq!(a.requests, b.requests);
                assert_eq!(a.p50, b.p50);
                assert_eq!(a.p95, b.p95);
            }
            assert_eq!(back.trace, s.trace);
            assert_eq!(back.store, s.store);
            assert_eq!(
                back.resident.shared_bytes,
                s.resident.shared_bytes
            );
            assert_eq!(back.latency_buckets, s.latency_buckets);
            assert_eq!(back.latency_sum, s.latency_sum);
            assert_eq!(back.adapt_generation, s.adapt_generation);
            assert_eq!(back.adapt_swaps, s.adapt_swaps);
            assert_eq!(back.adapt_last_drift, s.adapt_last_drift);
        }
    }

    #[test]
    fn snapshot_from_json_rejects_malformed_bodies() {
        use crate::jsonx::Json;
        // missing field
        let mut j = busy_snapshot().to_json();
        if let Json::Obj(fields) = &mut j {
            fields.retain(|(k, _)| k != "requests");
        }
        assert!(MetricsSnapshot::from_json(&j).is_err());
        // negative duration
        let mut j = busy_snapshot().to_json();
        if let Json::Obj(fields) = &mut j {
            for (k, v) in fields.iter_mut() {
                if k == "p50_ns" {
                    *v = Json::Num(-5.0);
                }
            }
        }
        assert!(MetricsSnapshot::from_json(&j).is_err());
        // wrong shape entirely
        assert!(MetricsSnapshot::from_json(&Json::Arr(vec![])).is_err());
    }
}
