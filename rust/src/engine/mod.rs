//! Unified serving engine: **one** construction path for every
//! deployment shape the MoPEQ system can serve.
//!
//! [`EngineBuilder`] composes the whole deployment declaratively —
//! model variant × [`WeightForm`] × [`PrecisionSource`] × backend ×
//! [`BatchPolicy`] × worker count × admission control — replacing the
//! old `ServerHandle::start` / `start_packed` and
//! `ModelExecutor::new` / `with_packed` constructor splits:
//!
//! ```no_run
//! use mopeq::engine::{Engine, PrecisionSource, WeightForm};
//! use mopeq::data::{gen_sample, Task};
//! use mopeq::rng::Rng;
//!
//! let engine = Engine::builder("dsvl2_tiny")
//!     .weight_form(WeightForm::Packed)
//!     .precision(PrecisionSource::mopeq()) // paper's allocation
//!     .workers(2)
//!     .queue_depth(64)
//!     .build()?;
//! let client = engine.client();
//! let sample = gen_sample(Task::Blink, engine.config(), &mut Rng::new(0));
//! let reply = client.submit(sample)?.wait()?;
//! let live = engine.metrics(); // queryable while serving
//! let stats = engine.shutdown()?;
//! # Ok::<(), anyhow::Error>(())
//! ```
//!
//! The **whole coordinator pipeline** is expressible in the builder:
//! [`PrecisionSource::Allocated`] parameterizes the allocation
//! (importance metric × granularity × bit palette × average-bits
//! budget, [`spec::AllocPolicy`]) and [`EngineBuilder::quantizer`]
//! selects the quantization function with its calibration capture
//! ([`spec::QuantSpec`]: RTN / SignRound / GPTQ / AWQ). Resolution runs
//! the shared [`spec::PreparedWeights`] pipeline — resolve → calibrate
//! → allocate → quantize/pack → strip — the same stages the coordinator
//! drives, so a deployment built here matches the paper tables' maps
//! and codes exactly.
//!
//! **Topology.** N worker threads each own a backend `Session` and a
//! `ModelExecutor` replica; every immutable argument is pre-sliced
//! **once** into Arc-shared [`SharedArgs`] (and, for packed
//! deployments, the packed [`PackedStore`] words) and stays shared all
//! the way into the executors (`Value::F32Shared` / `Value::Packed`
//! clone the `Arc`, no weight bytes are copied), so scaling workers
//! multiplies compute — not dense or packed weight memory
//! (`ResidentReport::shared_bytes` measures it). Requests flow through
//! one bounded MPMC queue —
//! a full queue rejects the submit with a typed [`Rejected::Busy`]
//! (admission control), and a request whose per-client deadline expires
//! while queued is answered with [`Rejected::Deadline`] instead of
//! being served stale or dropped.
//!
//! **Hot-swap.** A packed engine built with
//! [`EngineBuilder::reloadable`] can atomically re-point its expert
//! weights at a different precision map **while serving** — zero
//! requests dropped or rejected by the swap itself. The protocol
//! (driven by [`ReloadHandle::reload`]): re-pack the target map from
//! the retained reference weights, stage the new [`EngineWeights`]
//! beside the live ones, bump a generation counter, and nudge the
//! worker pool. Each worker observes the new generation at its next
//! queue pop (a request boundary — never mid-batch), rebuilds its
//! executor replica on the staged weights, acknowledges, and resumes;
//! queued jobs stay queued across the rebuild and are served by the
//! new weights. `reload` returns once every worker acknowledged, so a
//! reply obtained after it returns is bit-identical to an engine built
//! directly on the target map. The old store drains naturally as
//! workers drop their `Arc` clones.

pub mod metrics;
pub(crate) mod queue;
pub mod serve_config;
pub mod spec;
mod worker;

pub use metrics::{MetricsSnapshot, WorkerSnapshot};
pub use serve_config::ServeConfig;
pub use spec::{
    AllocPolicy, AvgBitsBudget, CalibSpec, PreparedWeights, Provenance,
    QuantSpec, SavedMap, SpecError,
};

use crate::config::{self, ModelConfig};
use crate::coordinator::executor::{ModelExecutor, MoeKernel, SharedArgs};
use crate::coordinator::QuantStats;
use crate::data::Sample;
use crate::moe::{PackedStore, PrecisionMap, WeightStore};
use crate::obs::health::{
    EventLog, HealthReport, HealthState, SloConfig, EVENT_CAPACITY,
};
use crate::obs::kern::{KernelEpoch, KernelStat};
use crate::obs::quality::{
    self, ProbeJob, QualitySnapshot, QualityStats, QualityTap,
};
use crate::obs::routing::{RoutingStats, TrafficSnapshot};
use crate::obs::trace::{TraceRing, TraceSpan, TraceSummary};
use crate::search::SearchSpec;
use crate::serve::BatchPolicy;
use crate::store::TieredStore;
use anyhow::{anyhow, bail, Result};
use metrics::Metrics;
use queue::JobQueue;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

/// How the engine holds (and executes) expert weights.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum WeightForm {
    /// dense f32 reference weights, fp16-accounted — no quantization
    #[default]
    Fp16,
    /// quantize→dequantize: experts rounded through their assigned
    /// integer codes but served as dense f32 (the legacy qdq path)
    DequantizedF32,
    /// serve straight from bit-packed codes: no dense f32 expert copy
    /// is resident, and `MetricsSnapshot::resident` proves it
    Packed,
}

impl WeightForm {
    pub fn label(&self) -> &'static str {
        match self {
            WeightForm::Fp16 => "Fp16",
            WeightForm::DequantizedF32 => "DequantizedF32",
            WeightForm::Packed => "Packed",
        }
    }
}

/// Where the per-expert precision map comes from.
#[derive(Clone, Debug, Default)]
pub enum PrecisionSource {
    /// fp16 reference — only valid with [`WeightForm::Fp16`]
    #[default]
    Reference,
    /// every expert at the same width
    Uniform(u8),
    /// a precomputed assignment
    Map(PrecisionMap),
    /// a JSON map artifact written by [`SavedMap::save`] /
    /// `mopeq allocate --out` — the allocate→serve round-trip
    MapFile(PathBuf),
    /// computed at build by the parameterized allocation policy
    /// (importance metric × granularity × palette × budget)
    Allocated(AllocPolicy),
    /// computed at build by the Pareto allocation search
    /// ([`crate::search::run_search`]): exact DP + local refinement
    /// over the cost model's size/error/throughput table — "the best
    /// map under this budget", not "the clustering heuristic capped"
    Searched(SearchSpec),
}

impl PrecisionSource {
    /// The paper's MoPEQ allocation — closed-form Hessian sensitivity →
    /// Algorithm 2 K-means over {2,3,4} bits, model-wise — i.e.
    /// [`PrecisionSource::Allocated`] of [`AllocPolicy::default`].
    pub fn mopeq() -> PrecisionSource {
        PrecisionSource::Allocated(AllocPolicy::default())
    }

    /// The searched counterpart of [`PrecisionSource::mopeq`]: the best
    /// map under `max_mean_bits` average bits
    /// ([`SearchSpec::avg_bits`]).
    pub fn searched(max_mean_bits: f64) -> PrecisionSource {
        PrecisionSource::Searched(SearchSpec::avg_bits(max_mean_bits))
    }
}

/// Typed admission/deadline rejection — the only ways the engine
/// declines work.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Rejected {
    /// the bounded queue is at capacity; retry later or scale workers
    Busy { depth: usize },
    /// the request's deadline expired before a worker reached it
    Deadline,
    /// the engine is shutting down (or has shut down)
    Closed,
}

impl Rejected {
    /// Stable machine-readable code — the **wire contract** (DESIGN.md
    /// §Network serving documents the full mapping table). These strings
    /// are load-bearing for network clients: never rename them.
    pub fn code(&self) -> &'static str {
        match self {
            Rejected::Busy { .. } => "busy",
            Rejected::Deadline => "deadline",
            Rejected::Closed => "closed",
        }
    }

    /// HTTP status the network front-end answers this rejection with:
    /// 429 Too Many Requests / 504 Gateway Timeout / 503 Service
    /// Unavailable.
    pub fn status(&self) -> u16 {
        match self {
            Rejected::Busy { .. } => 429,
            Rejected::Deadline => 504,
            Rejected::Closed => 503,
        }
    }

    /// Coarse client back-off hint for [`Rejected::Busy`]: the queue
    /// must drain `depth` jobs before a retry can be admitted, so the
    /// hint scales with the observed depth (5 ms per queued job, clamped
    /// to [10 ms, 1 s]). `None` for the non-retryable rejections.
    pub fn retry_after(&self) -> Option<Duration> {
        match self {
            Rejected::Busy { depth } => Some(Duration::from_millis(
                (*depth as u64 * 5).clamp(10, 1_000),
            )),
            Rejected::Deadline | Rejected::Closed => None,
        }
    }

    /// The machine-readable wire body (without the `{"error": …}`
    /// envelope the HTTP front-end wraps it in): stable `code`, HTTP
    /// `status`, the `Display` message, plus `depth` / `retry_after_ms`
    /// for `Busy`.
    pub fn to_json(&self) -> crate::jsonx::Json {
        use crate::jsonx::Json;
        let mut obj = vec![
            ("code".to_string(), Json::Str(self.code().to_string())),
            ("status".to_string(), Json::Num(self.status() as f64)),
            ("message".to_string(), Json::Str(self.to_string())),
        ];
        if let Rejected::Busy { depth } = self {
            obj.push(("depth".to_string(), Json::Num(*depth as f64)));
        }
        if let Some(hint) = self.retry_after() {
            obj.push((
                "retry_after_ms".to_string(),
                Json::Num(hint.as_millis() as f64),
            ));
        }
        Json::Obj(obj)
    }

    /// Parse a wire body back into the typed rejection — what the
    /// load-generator (and any Rust client) uses, so the in-process
    /// matchers keep working across the network boundary.
    pub fn from_json(j: &crate::jsonx::Json) -> Result<Rejected> {
        Ok(match j.req("code")?.as_str()? {
            "busy" => Rejected::Busy { depth: j.req("depth")?.as_usize()? },
            "deadline" => Rejected::Deadline,
            "closed" => Rejected::Closed,
            code => bail!("unknown rejection code `{code}`"),
        })
    }
}

impl std::fmt::Display for Rejected {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Rejected::Busy { depth } => {
                write!(f, "engine busy: queue at depth {depth}")
            }
            Rejected::Deadline => write!(f, "request deadline expired"),
            Rejected::Closed => write!(f, "engine is shut down"),
        }
    }
}

impl std::error::Error for Rejected {}

/// Engine reply for one request.
#[derive(Clone, Debug)]
pub struct Reply {
    pub answer: usize,
    pub correct: bool,
    /// end-to-end latency (submit → reply)
    pub latency: Duration,
    /// how many real requests shared the executed batch (≥ 1)
    pub batch_fill: usize,
}

/// One admitted request, queued for a worker.
pub(crate) struct Job {
    pub sample: Sample,
    pub enqueued: Instant,
    /// when a worker popped this job off the queue — set by the serve
    /// loop, the trace's queue-wait / batch-linger boundary
    pub popped: Option<Instant>,
    pub deadline: Option<Instant>,
    pub respond: mpsc::Sender<Result<Reply, Rejected>>,
}

/// The shared immutable weights every worker replica executes over:
/// every argument is pre-sliced once into Arc-shared [`SharedArgs`]
/// (and, for packed deployments, the packed expert words), so worker
/// count multiplies compute — never dense weight memory.
pub(crate) enum EngineWeights {
    Dense(Arc<SharedArgs>),
    Packed {
        backbone: Arc<SharedArgs>,
        experts: Arc<PackedStore>,
    },
    /// packed experts spilled to disk behind a bounded resident set
    /// (`--resident-bytes`) — the in-RAM `PackedStore` is dropped at
    /// build and every worker pages through the one shared store
    Tiered {
        backbone: Arc<SharedArgs>,
        store: Arc<TieredStore>,
    },
}

impl EngineWeights {
    fn exec_weights(&self) -> crate::coordinator::ExecWeights<'_> {
        match self {
            EngineWeights::Dense(args) => {
                crate::coordinator::ExecWeights::SharedDense(args)
            }
            EngineWeights::Packed { backbone, experts } => {
                crate::coordinator::ExecWeights::SharedPacked {
                    backbone,
                    experts,
                }
            }
            EngineWeights::Tiered { backbone, store } => {
                crate::coordinator::ExecWeights::SharedTiered {
                    backbone,
                    store,
                }
            }
        }
    }
}

/// Swap-protocol state shared between the reload path and the worker
/// pool. `generation` monotonically counts staged swaps; a worker whose
/// seen generation lags rebuilds on `staged` (kept — **every** worker
/// clones it, the slot is only replaced by the next stage) and records
/// its new generation in `acks[index]` so [`ReloadHandle::reload`] can
/// wait for the whole pool.
pub(crate) struct SwapState {
    pub(crate) generation: AtomicU64,
    pub(crate) staged: Mutex<Option<Arc<EngineWeights>>>,
    pub(crate) acks: Vec<AtomicU64>,
    /// completed swaps (every worker acknowledged)
    pub(crate) swaps: AtomicU64,
    /// last observed routing drift (f64 bits) — written by the adapt
    /// controller on every observation, swap or not
    pub(crate) last_drift: AtomicU64,
}

impl SwapState {
    fn new(workers: usize) -> SwapState {
        SwapState {
            generation: AtomicU64::new(0),
            staged: Mutex::new(None),
            acks: (0..workers).map(|_| AtomicU64::new(0)).collect(),
            swaps: AtomicU64::new(0),
            last_drift: AtomicU64::new(0),
        }
    }
}

pub(crate) struct Shared {
    pub(crate) queue: JobQueue,
    pub(crate) metrics: Metrics,
    /// live `[moe_layer][expert]` activation histogram (atomics)
    pub(crate) routing: RoutingStats,
    /// bounded window of completed request traces
    pub(crate) traces: TraceRing,
    /// the tiered expert store, when serving under `--resident-bytes`
    /// (behind a mutex so a hot-swap can re-point it)
    pub(crate) store: Mutex<Option<Arc<TieredStore>>>,
    /// hot-swap protocol state (generation, staged weights, acks)
    pub(crate) swap: SwapState,
    /// the precision map the pool currently serves — starts as the
    /// build-time map, advanced by each completed swap; what the
    /// observability plane joins traffic against
    pub(crate) pmap: Mutex<Option<PrecisionMap>>,
    /// engine epoch: the zero point of every trace `start_ns`,
    /// event and timeline timestamp
    pub(crate) epoch: Instant,
    /// kernel-counter baseline snapshotted at build, so per-engine
    /// views subtract other engines' (earlier tests') traffic out
    pub(crate) kern_epoch: KernelEpoch,
    /// bounded structured log of lifecycle events and SLO crossings
    pub(crate) events: EventLog,
    /// shadow-probe statistics (`--quality-sample` builds only)
    pub(crate) quality: Option<Arc<QualityStats>>,
    /// declared SLOs + per-check crossing memory
    pub(crate) health: HealthState,
}

impl Shared {
    /// The full snapshot every public path serves: counters + the trace
    /// summary and store accounting (which `Metrics` alone cannot see —
    /// the ring and store live here, beside it).
    fn snapshot(&self) -> MetricsSnapshot {
        let mut snap = self.metrics.snapshot(self.queue.len());
        snap.trace = self.traces.summary();
        snap.store =
            self.store.lock().unwrap().as_ref().map(|s| s.snapshot());
        snap.adapt_generation = self.swap.generation.load(Ordering::Acquire);
        snap.adapt_swaps = self.swap.swaps.load(Ordering::Relaxed);
        snap.adapt_last_drift =
            f64::from_bits(self.swap.last_drift.load(Ordering::Relaxed));
        snap
    }
}

/// Builder for an [`Engine`] — the single construction path for every
/// deployment shape (see the module docs for the grammar).
pub struct EngineBuilder {
    variant: String,
    weights: Option<WeightStore>,
    seed: u64,
    form: WeightForm,
    precision: PrecisionSource,
    quant: QuantSpec,
    backend: Option<String>,
    policy: BatchPolicy,
    workers: usize,
    queue_depth: usize,
    trace_buffer: usize,
    trace_sample: usize,
    resident_bytes: Option<usize>,
    store_path: Option<PathBuf>,
    prefetch: bool,
    reloadable: bool,
    quality_sample: usize,
    slo: SloConfig,
}

impl EngineBuilder {
    pub fn new(variant: impl Into<String>) -> EngineBuilder {
        EngineBuilder {
            variant: variant.into(),
            weights: None,
            seed: 0,
            form: WeightForm::Fp16,
            precision: PrecisionSource::Reference,
            quant: QuantSpec::default(),
            backend: None,
            policy: BatchPolicy::default(),
            workers: 1,
            queue_depth: 128,
            trace_buffer: 256,
            trace_sample: 1,
            resident_bytes: None,
            store_path: None,
            prefetch: true,
            reloadable: false,
            quality_sample: 0,
            slo: SloConfig::default(),
        }
    }

    /// Serve these weights (trained or reference). Without this the
    /// engine uses the variant's deterministic init at [`seed`](Self::seed).
    pub fn weights(mut self, ws: WeightStore) -> Self {
        self.weights = Some(ws);
        self
    }

    /// Seed for deterministic weight init (ignored when
    /// [`weights`](Self::weights) is given) and for Algorithm 2.
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn weight_form(mut self, form: WeightForm) -> Self {
        self.form = form;
        self
    }

    pub fn precision(mut self, src: PrecisionSource) -> Self {
        self.precision = src;
        self
    }

    /// "Serve the best deployment under `max_mean_bits` average bits":
    /// packed weight form + [`PrecisionSource::Searched`] of
    /// [`SearchSpec::avg_bits`] — build runs the Pareto allocation
    /// search (exact DP + refinement over the size/error/throughput
    /// cost model) and serves the winning map directly. Compose
    /// [`precision`](Self::precision) with a hand-built [`SearchSpec`]
    /// for non-default metrics, palettes, probes, or byte budgets.
    pub fn auto(self, max_mean_bits: f64) -> Self {
        self.weight_form(WeightForm::Packed)
            .precision(PrecisionSource::searched(max_mean_bits))
    }

    /// Which quantization function fills the precision map when the
    /// form quantizes (`DequantizedF32` / `Packed`), with its
    /// calibration capture. Default: calibration-free RTN. A
    /// calib-needing quantizer (`Quantizer::needs_calib`) without a
    /// [`CalibSpec`] fails `build()` with a typed
    /// [`SpecError::MissingCalib`].
    pub fn quantizer(mut self, quant: QuantSpec) -> Self {
        self.quant = quant;
        self
    }

    /// Backend choice per worker: `"native"` or `"xla"`. Default
    /// follows `MOPEQ_BACKEND` (native when unset).
    pub fn backend(mut self, choice: impl Into<String>) -> Self {
        self.backend = Some(choice.into());
        self
    }

    pub fn batch_policy(mut self, policy: BatchPolicy) -> Self {
        self.policy = policy;
        self
    }

    /// Worker threads (≥ 1). Each owns a session + executor replica;
    /// expert weights are shared, so this scales compute not memory.
    pub fn workers(mut self, n: usize) -> Self {
        self.workers = n.max(1);
        self
    }

    /// Admission-control bound: jobs queued beyond this are rejected
    /// with [`Rejected::Busy`] instead of buffered.
    pub fn queue_depth(mut self, depth: usize) -> Self {
        self.queue_depth = depth.max(1);
        self
    }

    /// Completed-trace ring capacity (default 256, clamped to ≥ 1):
    /// how many recent requests keep their per-stage timing breakdown
    /// for `GET /v1/traces` and the snapshot's trace summary.
    pub fn trace_buffer(mut self, capacity: usize) -> Self {
        self.trace_buffer = capacity;
        self
    }

    /// Trace sampling: keep 1-in-`n` completed request traces
    /// (clamped to ≥ 1, i.e. keep all). The completion counter still
    /// counts every request, so high-QPS deployments keep a useful
    /// ring window without the per-request push cost.
    pub fn trace_sample(mut self, n: usize) -> Self {
        self.trace_sample = n.max(1);
        self
    }

    /// Serve the packed experts from a disk-backed tiered store whose
    /// resident set is bounded by `bytes` of real expert heap
    /// (u32-padded words + f32 scales) — the "model bigger than RAM"
    /// deployment. Requires [`WeightForm::Packed`]. The cap must fit
    /// the largest single expert.
    pub fn resident_bytes(mut self, bytes: usize) -> Self {
        self.resident_bytes = Some(bytes);
        self
    }

    /// Where the tiered store's artifact file lives. Default: a
    /// per-engine temp file, deleted on shutdown; an explicit path is
    /// kept on disk for reuse.
    pub fn store_path(mut self, path: impl Into<PathBuf>) -> Self {
        self.store_path = Some(path.into());
        self
    }

    /// Enable/disable the tiered store's background predictive
    /// prefetch thread (default on; demand paging only when off).
    pub fn prefetch(mut self, on: bool) -> Self {
        self.prefetch = on;
        self
    }

    /// Keep what a live precision-map hot-swap needs: the reference
    /// weights (experts included) and the quantization spec, so
    /// [`Engine::reloader`] can re-pack a new map and swap the pool
    /// onto it without a restart. Opt-in because retaining the dense
    /// expert weights costs exactly the memory the packed form
    /// otherwise saves. Requires [`WeightForm::Packed`].
    pub fn reloadable(mut self, on: bool) -> Self {
        self.reloadable = on;
        self
    }

    /// Shadow-reference quality probes: re-execute 1-in-`n` completed
    /// requests on the retained dense reference in a background
    /// thread, recording logit MSE, top-1 agreement, and per-(layer,
    /// expert) error attribution (`GET /v1/quality`). `0` disables
    /// (default). Requires [`reloadable`](Self::reloadable) — the
    /// probes execute on exactly the dense weights the reload path
    /// already retains.
    pub fn quality_sample(mut self, n: usize) -> Self {
        self.quality_sample = n;
        self
    }

    /// Declared service objectives for the health engine: `GET
    /// /healthz` grades every check against these (missed = degraded,
    /// missed 2× = unhealthy → 503) and threshold crossings land in
    /// the `GET /v1/events` log.
    pub fn slo(mut self, slo: SloConfig) -> Self {
        self.slo = slo;
        self
    }

    /// Resolve the deployment through the [`spec::PreparedWeights`]
    /// pipeline (resolve → calibrate → allocate → quantize/pack →
    /// strip), then spawn and warm the worker pool. Returns once every
    /// worker is ready to serve. Invalid form × precision × quantizer
    /// combinations fail here with a typed [`SpecError`] before any
    /// thread is spawned.
    pub fn build(self) -> Result<Engine> {
        let cfg = config::variant(&self.variant)?;
        let ws = match self.weights {
            Some(ws) => {
                if ws.variant != cfg.name {
                    bail!(
                        "weights are for `{}`, engine variant is `{}`",
                        ws.variant,
                        cfg.name
                    );
                }
                ws
            }
            None => WeightStore::init(&cfg, &crate::moe::local_meta(&cfg), self.seed),
        };
        if self.reloadable && self.form != WeightForm::Packed {
            bail!(
                "reloadable swaps the packed expert store — it requires \
                 WeightForm::Packed, not {}",
                self.form.label()
            );
        }
        if self.quality_sample > 0 && !self.reloadable {
            bail!(
                "quality probes re-execute sampled requests on the \
                 retained dense reference — quality_sample requires \
                 reloadable(true)"
            );
        }
        // the reload path re-packs new maps from the reference weights,
        // which the packed prepare pipeline otherwise strips — retain a
        // full copy only when the deployment opted in
        let retained = self.reloadable.then(|| ws.clone());

        let backend = self.backend.clone();
        let prepared = PreparedWeights::prepare(
            &cfg,
            ws,
            self.form,
            &self.precision,
            &self.quant,
            self.seed,
            || worker::open_session(backend.as_deref()),
        )?;
        let PreparedWeights { weights, pmap, provenance, stats } = prepared;

        // `--resident-bytes`: spill the packed experts to the tiered
        // store's disk artifact and drop the in-RAM copy — from here on
        // every worker pages experts through the bounded resident set
        let mut store_handle: Option<Arc<TieredStore>> = None;
        let weights = match (self.resident_bytes, weights) {
            (Some(cap), EngineWeights::Packed { backbone, experts }) => {
                let path = match &self.store_path {
                    Some(p) => p.clone(),
                    None => default_store_path(&self.variant),
                };
                let keep = self.store_path.is_some();
                let store = Arc::new(TieredStore::build(
                    &experts,
                    &path,
                    cap,
                    self.prefetch,
                    keep,
                )?);
                drop(experts);
                store_handle = Some(store.clone());
                EngineWeights::Tiered { backbone, store }
            }
            (Some(_), _) => bail!(
                "resident_bytes bounds the packed expert store — it \
                 requires WeightForm::Packed"
            ),
            (None, w) => w,
        };

        let weights = Arc::new(weights);
        let reload = retained.map(|ws_full| {
            let backbone = match weights.as_ref() {
                EngineWeights::Packed { backbone, .. }
                | EngineWeights::Tiered { backbone, .. } => backbone.clone(),
                EngineWeights::Dense(_) => {
                    unreachable!("reloadable requires WeightForm::Packed")
                }
            };
            Arc::new(ReloadCtx {
                cfg: cfg.clone(),
                ws: ws_full,
                quant: self.quant.clone(),
                seed: self.seed,
                backend: self.backend.clone(),
                backbone,
                resident_bytes: self.resident_bytes,
                prefetch: self.prefetch,
                lock: Mutex::new(()),
            })
        });
        // the quality plane: preallocated stats + a bounded probe
        // channel whose worker-side taps never block the serving path
        let epoch = Instant::now();
        let quality_stats = (self.quality_sample > 0).then(|| {
            Arc::new(QualityStats::new(
                cfg.moe_layers(),
                cfg.experts,
                self.quality_sample,
            ))
        });
        let (quality_tap, probe_rx) = match &quality_stats {
            Some(stats) => {
                let (tx, rx) = mpsc::sync_channel::<ProbeJob>(64);
                (Some(QualityTap::new(stats.clone(), tx)), Some(rx))
            }
            None => (None, None),
        };
        let shared = Arc::new(Shared {
            queue: JobQueue::new(self.queue_depth),
            metrics: Metrics::new(self.workers),
            routing: RoutingStats::new(cfg.moe_layers(), cfg.experts),
            traces: TraceRing::sampled(self.trace_buffer, self.trace_sample),
            store: Mutex::new(store_handle),
            swap: SwapState::new(self.workers),
            pmap: Mutex::new(pmap.clone()),
            epoch,
            kern_epoch: KernelEpoch::capture(),
            events: EventLog::new(EVENT_CAPACITY, epoch),
            quality: quality_stats,
            health: HealthState::new(self.slo.clone()),
        });
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let mut handles = Vec::with_capacity(self.workers);
        for index in 0..self.workers {
            let wc = worker::WorkerConfig {
                index,
                cfg: cfg.clone(),
                weights: weights.clone(),
                backend: self.backend.clone(),
                policy: self.policy,
                shared: shared.clone(),
                quality: quality_tap.clone(),
            };
            let tx = ready_tx.clone();
            handles.push(
                std::thread::Builder::new()
                    .name(format!("mopeq-engine-{index}"))
                    .spawn(move || worker::run(wc, tx))?,
            );
        }
        drop(ready_tx);
        // the workers hold the only remaining senders: when the pool
        // drains at shutdown the probe channel disconnects and the
        // probe thread exits its recv loop
        drop(quality_tap);
        let mut first_err: Option<anyhow::Error> = None;
        for _ in 0..self.workers {
            let outcome = ready_rx
                .recv()
                .unwrap_or_else(|_| Err(anyhow!("a worker died during warmup")));
            if let Err(e) = outcome {
                if first_err.is_none() {
                    first_err = Some(e);
                }
            }
        }
        if let Some(e) = first_err {
            shared.queue.close();
            for h in handles {
                let _ = h.join();
            }
            return Err(e);
        }
        // every engine argument is Arc-shared — one worker's measured
        // residency must report its whole weight footprint as shared,
        // i.e. N workers scale compute, not dense memory (host-measured
        // backends only: device-resident reports measure 0 here). A
        // violation shuts the pool down cleanly and errors — never a
        // panic over live worker threads.
        let resident = shared.metrics.snapshot(0).resident;
        if resident.backbone_bytes > 0
            && resident.shared_bytes
                != resident.backbone_bytes + resident.expert_heap_bytes
        {
            shared.queue.close();
            for h in handles {
                let _ = h.join();
            }
            bail!(
                "engine invariant violated: only {} of {} resident \
                 weight bytes are Arc-shared across workers",
                resident.shared_bytes,
                resident.backbone_bytes + resident.expert_heap_bytes
            );
        }
        // every worker is warm: start the serving clock now so
        // throughput never includes compile/warmup cost
        shared.metrics.mark_started();
        // the probe thread owns its own session + dense reference
        // executor, so probing never contends with a serving replica
        let probe = match (probe_rx, &reload) {
            (Some(rx), Some(ctx)) => {
                let shared_p = shared.clone();
                let ctx = ctx.clone();
                Some(
                    std::thread::Builder::new()
                        .name("mopeq-quality".to_string())
                        .spawn(move || probe_loop(rx, shared_p, ctx))?,
                )
            }
            _ => None,
        };
        shared.events.push(
            "engine_start",
            &format!(
                "{} worker(s) serving {} ({})",
                self.workers,
                cfg.name,
                self.form.label()
            ),
        );
        Ok(Engine {
            shared,
            workers: handles,
            cfg,
            pmap,
            provenance,
            stats,
            reload,
            probe,
        })
    }
}

/// Probe-thread body: drain sampled requests off the bounded channel
/// and re-execute each on the dense f32 reference (the same retained
/// weights the reload path repacks from), folding logit MSE, top-1
/// agreement, and per-(layer, expert) error attribution into
/// [`QualityStats`]. Exits when every worker tap has dropped. A probe
/// that fails counts `failed` and logs a `probe_failure` event — it
/// never takes the engine down.
fn probe_loop(
    rx: mpsc::Receiver<ProbeJob>,
    shared: Arc<Shared>,
    ctx: Arc<ReloadCtx>,
) {
    let Some(stats) = shared.quality.clone() else { return };
    let session = match worker::open_session(ctx.backend.as_deref()) {
        Ok(s) => s,
        Err(e) => {
            probe_sink(&rx, &shared, &stats, &e);
            return;
        }
    };
    let exec = match ModelExecutor::new(&session, &ctx.cfg, &ctx.ws) {
        Ok(ex) => ex,
        Err(e) => {
            probe_sink(&rx, &shared, &stats, &e);
            return;
        }
    };
    while let Ok(job) = rx.recv() {
        let start = Instant::now();
        let start_ns =
            start.saturating_duration_since(shared.epoch).as_nanos() as u64;
        match run_probe(&exec, &ctx.cfg, &job) {
            Ok((mse, agree, contributions)) => {
                stats.record_probe(
                    quality::ProbeRecord {
                        key: quality::sample_key(&job.sample.tokens),
                        task: job.sample.task.label().to_string(),
                        generation: job.generation,
                        mse,
                        agree,
                        start_ns,
                        dur_ns: start.elapsed().as_nanos() as u64,
                    },
                    &contributions,
                );
            }
            Err(e) => {
                stats.count_failed();
                shared.events.push("probe_failure", &format!("{e}"));
            }
        }
    }
}

/// A probe thread that could not build its reference executor still
/// drains the channel (so worker `try_send`s disconnect-drop instead
/// of filling up) and counts every job failed.
fn probe_sink(
    rx: &mpsc::Receiver<ProbeJob>,
    shared: &Shared,
    stats: &QualityStats,
    err: &anyhow::Error,
) {
    shared
        .events
        .push("probe_failure", &format!("probe thread disabled: {err}"));
    while rx.recv().is_ok() {
        stats.count_failed();
    }
}

/// One shadow probe: forward the sampled request through the dense
/// reference and compare against what the packed path served.
fn run_probe(
    exec: &ModelExecutor,
    cfg: &ModelConfig,
    job: &ProbeJob,
) -> Result<(f64, bool, Vec<Vec<f64>>)> {
    let samples = [job.sample.clone()];
    let (tokens, vis) = crate::data::pack_batch(&samples, cfg);
    let out = exec.forward(&tokens, &vis, false)?;
    let dense = out.logits.index0(0).data;
    if dense.len() != job.logits.len() {
        bail!(
            "probe logits width {} != served width {}",
            dense.len(),
            job.logits.len()
        );
    }
    let mse = quality::probe_mse(&job.logits, &dense);
    let agree = out.logits.argmax_rows()[0] == job.pred;
    Ok((mse, agree, quality::attribute(mse, &out.counts)))
}

/// Unique per-engine artifact path for an auto-created tiered store
/// (pid + a process-wide sequence, so concurrent engines in one test
/// binary never collide). The file is deleted when the store drops.
fn default_store_path(variant: &str) -> PathBuf {
    static SEQ: AtomicU64 = AtomicU64::new(0);
    let n = SEQ.fetch_add(1, Ordering::Relaxed);
    std::env::temp_dir().join(format!(
        "mopeq_store_{variant}_{}_{n}.bin",
        std::process::id()
    ))
}

/// A running deployment: worker pool + shared queue + live metrics.
pub struct Engine {
    shared: Arc<Shared>,
    workers: Vec<std::thread::JoinHandle<Result<()>>>,
    cfg: ModelConfig,
    /// the resolved per-expert map this engine serves (None for fp16)
    pmap: Option<PrecisionMap>,
    /// allocation provenance (Allocated sources and MapFiles carrying
    /// one)
    provenance: Option<Provenance>,
    /// quantization stats from the build (None for fp16)
    stats: Option<QuantStats>,
    /// everything a live map hot-swap needs (builds with
    /// [`EngineBuilder::reloadable`] only)
    reload: Option<Arc<ReloadCtx>>,
    /// the shadow-probe thread (`--quality-sample` builds only),
    /// joined at shutdown once every worker tap has dropped
    probe: Option<std::thread::JoinHandle<()>>,
}

impl Engine {
    /// Start composing a deployment for a model variant.
    pub fn builder(variant: impl Into<String>) -> EngineBuilder {
        EngineBuilder::new(variant)
    }

    pub fn config(&self) -> &ModelConfig {
        &self.cfg
    }

    /// The precision map the engine resolved at build (None = fp16
    /// reference) — what `MetricsSnapshot::resident` accounting is
    /// checked against.
    pub fn precision_map(&self) -> Option<&PrecisionMap> {
        self.pmap.as_ref()
    }

    /// Provenance of the allocation this engine serves (metric,
    /// granularity, palette, per-layer mean bits) — `Some` for
    /// [`PrecisionSource::Allocated`] builds and for map files that
    /// carry one.
    pub fn provenance(&self) -> Option<&Provenance> {
        self.provenance.as_ref()
    }

    /// Quantization stats from the build-time pack (None for fp16).
    pub fn quant_stats(&self) -> Option<&QuantStats> {
        self.stats.as_ref()
    }

    /// The resolved deployment as a saveable JSON artifact — what
    /// `mopeq allocate --out` writes; `None` for the fp16 reference.
    pub fn saved_map(&self) -> Option<SavedMap> {
        self.pmap.as_ref().map(|map| SavedMap {
            variant: self.cfg.name.to_string(),
            map: map.clone(),
            provenance: self.provenance.clone(),
        })
    }

    /// A cheap client session (an `Arc` clone). Clients are `Send` and
    /// independent — hand one to each request thread.
    pub fn client(&self) -> Client {
        Client { shared: self.shared.clone(), deadline: None }
    }

    /// Live telemetry — queryable **while serving**, not only at
    /// shutdown.
    pub fn metrics(&self) -> MetricsSnapshot {
        self.shared.snapshot()
    }

    /// A cheap `Send + Clone` handle onto the live telemetry (an `Arc`
    /// clone, like [`client`](Engine::client)) — what the network
    /// front-end's connection threads serve `GET /metrics` from without
    /// borrowing the engine itself.
    pub fn metrics_handle(&self) -> MetricsHandle {
        MetricsHandle { shared: self.shared.clone() }
    }

    /// A cheap `Send + Clone` handle onto the observability state:
    /// completed traces, the live routing histogram joined with the
    /// precision map, and the trace summary. Like
    /// [`metrics_handle`](Engine::metrics_handle) it outlives the
    /// engine borrow — grab one before handing the engine to the
    /// network server, and it keeps reading the same shared state
    /// (including after shutdown, for `--traffic-out`).
    pub fn observer(&self) -> ObsHandle {
        ObsHandle { shared: self.shared.clone(), cfg: self.cfg.clone() }
    }

    /// A cheap `Send + Clone` handle onto the hot-swap path — `Some`
    /// only for builds that opted in via
    /// [`EngineBuilder::reloadable`]. Like the other handles it
    /// outlives the engine borrow: grab it before handing the engine
    /// to the network server, hand clones to the adapt controller and
    /// the `/v1/reload` route.
    pub fn reloader(&self) -> Option<ReloadHandle> {
        self.reload.as_ref().map(|ctx| ReloadHandle {
            shared: self.shared.clone(),
            ctx: ctx.clone(),
        })
    }

    /// Stop admissions, drain every queued job through the workers,
    /// join them, and return the final snapshot.
    pub fn shutdown(mut self) -> Result<MetricsSnapshot> {
        self.shared.queue.close();
        let mut first_err: Option<anyhow::Error> = None;
        for h in self.workers.drain(..) {
            let outcome = h
                .join()
                .map_err(|_| anyhow!("an engine worker panicked"))
                .and_then(|r| r);
            if let Err(e) = outcome {
                if first_err.is_none() {
                    first_err = Some(e);
                }
            }
        }
        // the joined workers dropped the last probe senders: the probe
        // thread's recv loop has ended, so this join cannot hang
        if let Some(h) = self.probe.take() {
            let _ = h.join();
        }
        if let Some(e) = first_err {
            return Err(e);
        }
        Ok(self.shared.snapshot())
    }
}

impl Drop for Engine {
    /// An engine dropped without [`shutdown`](Engine::shutdown) (early
    /// `?` return, panic unwind) must not strand its worker threads
    /// blocked on an open queue forever: close, let them drain, join.
    fn drop(&mut self) {
        self.shared.queue.close();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
        if let Some(h) = self.probe.take() {
            let _ = h.join();
        }
    }
}

/// What a [`ReloadHandle`] needs to re-pack and swap a new precision
/// map: the reference weights (experts retained), the build's
/// quantization spec and seed, and the Arc-shared backbone the swap
/// reuses unchanged (only expert stores are replaced — the backbone
/// never re-quantizes, so it stays shared across generations).
pub(crate) struct ReloadCtx {
    cfg: ModelConfig,
    ws: WeightStore,
    quant: QuantSpec,
    seed: u64,
    backend: Option<String>,
    backbone: Arc<SharedArgs>,
    resident_bytes: Option<usize>,
    prefetch: bool,
    /// serializes concurrent reloads (controller + `/v1/reload`)
    lock: Mutex<()>,
}

/// A `Send + Clone` handle onto the hot-swap path, detached from the
/// engine's lifetime borrow (same pattern as [`MetricsHandle`]).
/// Drives zero-downtime precision-map swaps and feeds the adapt
/// controller its routing observations.
#[derive(Clone)]
pub struct ReloadHandle {
    shared: Arc<Shared>,
    ctx: Arc<ReloadCtx>,
}

impl ReloadHandle {
    /// Atomically re-point the serving pool at `saved`'s precision map
    /// without dropping a request (the module docs describe the
    /// protocol). Returns the new weight generation once **every**
    /// worker serves the new map; concurrent reloads serialize.
    pub fn reload(&self, saved: &SavedMap) -> Result<u64> {
        let _serialized = self.ctx.lock.lock().unwrap();
        if !self.shared.queue.is_open() {
            bail!("engine is shut down; nothing to reload");
        }
        if saved.variant != self.ctx.cfg.name {
            return Err(SpecError::VariantMismatch {
                expected: self.ctx.cfg.name.to_string(),
                found: saved.variant.clone(),
            }
            .into());
        }
        spec::check_map(&self.ctx.cfg, &saved.map)?;
        // re-pack the target map through the same quantize stage the
        // build ran — bit-exact with an engine built on this map
        let session = if self.ctx.quant.quantizer.needs_calib() {
            Some(worker::open_session(self.ctx.backend.as_deref())?)
        } else {
            None
        };
        let (store, _stats) = self.ctx.quant.pack(
            session.as_ref(),
            &self.ctx.cfg,
            &self.ctx.ws,
            &saved.map,
            MoeKernel::default(),
            self.ctx.seed,
        )?;
        let mut tiered_handle: Option<Arc<TieredStore>> = None;
        let staged = match self.ctx.resident_bytes {
            Some(cap) => {
                let path = default_store_path(self.ctx.cfg.name);
                let tiered = Arc::new(TieredStore::build(
                    &store,
                    &path,
                    cap,
                    self.ctx.prefetch,
                    false,
                )?);
                tiered_handle = Some(tiered.clone());
                EngineWeights::Tiered {
                    backbone: self.ctx.backbone.clone(),
                    store: tiered,
                }
            }
            None => EngineWeights::Packed {
                backbone: self.ctx.backbone.clone(),
                experts: Arc::new(store),
            },
        };
        // stage → bump → nudge: every worker rebuilds at its next
        // request boundary; queued jobs wait and are served by the new
        // weights, never dropped
        *self.shared.swap.staged.lock().unwrap() = Some(Arc::new(staged));
        let generation =
            self.shared.swap.generation.fetch_add(1, Ordering::AcqRel) + 1;
        self.shared.queue.nudge();
        loop {
            let all_acked = self
                .shared
                .swap
                .acks
                .iter()
                .all(|a| a.load(Ordering::Acquire) >= generation);
            if all_acked {
                break;
            }
            if !self.shared.queue.is_open() {
                bail!("engine closed while a reload was in flight");
            }
            std::thread::sleep(Duration::from_millis(1));
        }
        // the pool serves the new map everywhere: flip the
        // observability plane over to it
        *self.shared.pmap.lock().unwrap() = Some(saved.map.clone());
        *self.shared.store.lock().unwrap() = tiered_handle;
        self.shared.swap.swaps.fetch_add(1, Ordering::Relaxed);
        // the swap is live: close the old map's quality window so the
        // new generation's agreement/MSE reads separately, and log it
        if let Some(q) = &self.shared.quality {
            q.rotate(generation);
        }
        self.shared.events.push(
            "swap",
            &format!("weight generation {generation} live"),
        );
        Ok(generation)
    }

    /// The precision map the pool currently serves.
    pub fn live_map(&self) -> PrecisionMap {
        self.shared
            .pmap
            .lock()
            .unwrap()
            .clone()
            .expect("a reloadable engine always serves a precision map")
    }

    /// Current weight generation (0 until the first completed swap).
    pub fn generation(&self) -> u64 {
        self.shared.swap.generation.load(Ordering::Acquire)
    }

    /// The live cumulative routing histogram — what the adapt
    /// controller windows into drift observations.
    pub fn routing_counts(&self) -> Vec<Vec<u64>> {
        self.shared.routing.counts()
    }

    /// Whether the engine still admits work (false once shutdown
    /// began) — the controller's exit signal.
    pub fn is_open(&self) -> bool {
        self.shared.queue.is_open()
    }

    /// Record the controller's latest observed drift distance into the
    /// metrics plane (`adapt_last_drift`, `mopeq_adapt_drift`).
    pub fn record_drift(&self, distance: f64) {
        self.shared
            .swap
            .last_drift
            .store(distance.to_bits(), Ordering::Relaxed);
    }

    /// Append a structured lifecycle event (`drift`, `swap_failed`, …)
    /// to the engine's bounded event log (`GET /v1/events`).
    pub fn note(&self, kind: &str, detail: &str) {
        self.shared.events.push(kind, detail);
    }
}

/// A live-telemetry handle detached from the [`Engine`]'s lifetime
/// borrow: snapshots stay consistent while serving and keep working
/// during shutdown drain (they read the same counters
/// [`Engine::metrics`] does).
#[derive(Clone)]
pub struct MetricsHandle {
    shared: Arc<Shared>,
}

impl MetricsHandle {
    pub fn snapshot(&self) -> MetricsSnapshot {
        self.shared.snapshot()
    }
}

/// A `Send + Clone` handle onto the engine's observability state,
/// detached from the engine's lifetime borrow (same pattern as
/// [`MetricsHandle`]). Serves `GET /v1/traces` / `GET /v1/experts`
/// and the `--traffic-out` export.
#[derive(Clone)]
pub struct ObsHandle {
    shared: Arc<Shared>,
    cfg: ModelConfig,
}

impl ObsHandle {
    /// The trace ring's current window, oldest first.
    pub fn traces(&self) -> Vec<TraceSpan> {
        self.shared.traces.snapshot()
    }

    /// Per-stage percentiles over that window.
    pub fn trace_summary(&self) -> TraceSummary {
        self.shared.traces.summary()
    }

    pub fn trace_capacity(&self) -> usize {
        self.shared.traces.capacity()
    }

    /// The live routing histogram joined with the **currently served**
    /// precision map (hot-swaps included) — the `GET /v1/experts` body
    /// and the `--traffic-out` artifact.
    pub fn traffic(&self) -> TrafficSnapshot {
        let pmap = self.shared.pmap.lock().unwrap().clone();
        let store = self.shared.store.lock().unwrap().as_ref().map(|s| s.snapshot());
        TrafficSnapshot::capture(
            &self.shared.routing,
            &self.cfg,
            pmap.as_ref(),
            store,
        )
    }

    /// The `GET /v1/traces` wire body: ring shape + summary + spans.
    pub fn traces_json(&self) -> crate::jsonx::Json {
        self.traces_json_with(None, None)
    }

    /// `traces_json` with the `?limit=N` / `?stage=<name>` query
    /// filters applied: `limit` keeps only the newest N spans, `stage`
    /// projects each span down to that one stage's duration (callers
    /// validate the stage name against
    /// [`STAGE_NAMES`](crate::obs::trace::STAGE_NAMES) + `total`).
    pub fn traces_json_with(
        &self,
        limit: Option<usize>,
        stage: Option<&str>,
    ) -> crate::jsonx::Json {
        use crate::jsonx::Json;
        let summary = self.trace_summary();
        let mut spans = self.traces();
        if let Some(n) = limit {
            let skip = spans.len().saturating_sub(n);
            spans.drain(..skip);
        }
        Json::Obj(vec![
            (
                "capacity".into(),
                Json::Num(self.trace_capacity() as f64),
            ),
            (
                "completed".into(),
                Json::Num(summary.completed as f64),
            ),
            ("summary".into(), summary.to_json()),
            (
                "traces".into(),
                Json::Arr(
                    spans
                        .iter()
                        .map(|s| match stage {
                            None => s.to_json(),
                            Some(name) => project_stage(s, name),
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// Per-engine kernel counters: the process-global per-width
    /// tallies minus the baseline snapshotted when this engine was
    /// built, so two engines in one process never cross-contaminate.
    pub fn kernels(&self) -> Vec<KernelStat> {
        self.shared.kern_epoch.delta()
    }

    /// The quality plane's snapshot, joined with the currently served
    /// precision map's bits (hot-swaps included) — `None` unless the
    /// engine was built with a quality sample rate.
    pub fn quality(&self) -> Option<QualitySnapshot> {
        self.shared.quality.as_ref().map(|q| {
            let bits = self
                .shared
                .pmap
                .lock()
                .unwrap()
                .as_ref()
                .map(|m| m.bits.clone());
            q.snapshot(self.cfg.name, bits)
        })
    }

    /// The `GET /v1/quality` wire body.
    pub fn quality_json(&self) -> Option<crate::jsonx::Json> {
        self.quality().map(|s| s.to_json())
    }

    /// The `GET /v1/events` wire body: the bounded structured log of
    /// lifecycle events and SLO crossings.
    pub fn events_json(&self) -> crate::jsonx::Json {
        self.shared.events.to_json()
    }

    /// Evaluate the declared SLOs against a live snapshot; status
    /// changes land one crossing event each in the event log. The
    /// upgraded `GET /healthz` body.
    pub fn health(&self) -> HealthReport {
        let snap = self.shared.snapshot();
        let window = self.shared.quality.as_ref().map(|q| q.window());
        self.shared.health.check(
            &snap,
            window.as_ref(),
            &self.shared.events,
        )
    }

    /// The `GET /v1/timeline` wire body: trace spans, probe records,
    /// lifecycle events, and kernel/store counters rendered as one
    /// Chrome Trace Event JSON array (Perfetto-loadable).
    pub fn timeline_json(&self) -> crate::jsonx::Json {
        let spans = self.traces();
        let probes = self
            .shared
            .quality
            .as_ref()
            .map(|q| q.snapshot(self.cfg.name, None).probes)
            .unwrap_or_default();
        let events = self.shared.events.events();
        let kernels = self.kernels();
        let store = self
            .shared
            .store
            .lock()
            .unwrap()
            .as_ref()
            .map(|s| s.snapshot());
        crate::obs::timeline::chrome_trace(
            &spans,
            &probes,
            &events,
            &kernels,
            store.as_ref(),
            self.shared.epoch.elapsed().as_nanos() as u64,
        )
    }
}

/// Project one span down to a single stage:
/// `{worker, batch_fill, start_ns, <stage>_ns}`. Unknown names fall
/// back to the full span (route-level validation rejects them first).
fn project_stage(s: &TraceSpan, name: &str) -> crate::jsonx::Json {
    use crate::jsonx::Json;
    let d = if name == "total" {
        Some(s.total)
    } else {
        s.stages()
            .iter()
            .find(|(n, _)| *n == name)
            .map(|&(_, d)| d)
    };
    match d {
        None => s.to_json(),
        Some(d) => Json::Obj(vec![
            ("worker".into(), Json::Num(s.worker as f64)),
            ("batch_fill".into(), Json::Num(s.batch_fill as f64)),
            ("start_ns".into(), Json::Num(s.start_ns as f64)),
            (
                format!("{name}_ns"),
                Json::Num(d.as_nanos() as f64),
            ),
        ]),
    }
}

/// A typed client session over a running engine.
#[derive(Clone)]
pub struct Client {
    shared: Arc<Shared>,
    deadline: Option<Duration>,
}

impl Client {
    /// Per-request deadline: a request still queued when it expires is
    /// answered with [`Rejected::Deadline`].
    pub fn with_deadline(mut self, deadline: Duration) -> Client {
        self.deadline = Some(deadline);
        self
    }

    /// Submit a request past admission control. `Err(Busy)` when the
    /// bounded queue is full, `Err(Closed)` after shutdown.
    pub fn submit(&self, sample: Sample) -> Result<Ticket, Rejected> {
        let (tx, rx) = mpsc::channel();
        let now = Instant::now();
        let job = Job {
            sample,
            enqueued: now,
            popped: None,
            deadline: self.deadline.map(|d| now + d),
            respond: tx,
        };
        // count the attempt *before* the push: once the job is visible
        // in the queue a worker may answer it, and a concurrent
        // snapshot must never read `requests > submitted`
        self.shared.metrics.count_submitted();
        match self.shared.queue.push(job) {
            Ok(()) => Ok(Ticket { rx }),
            Err(r) => {
                self.shared.metrics.uncount_submitted();
                if matches!(r, Rejected::Busy { .. }) {
                    self.shared.metrics.count_busy();
                }
                Err(r)
            }
        }
    }

    /// Submit and block for the reply.
    pub fn call(&self, sample: Sample) -> Result<Reply, Rejected> {
        self.submit(sample)?.wait()
    }
}

/// The pending reply for one submitted request.
pub struct Ticket {
    rx: mpsc::Receiver<Result<Reply, Rejected>>,
}

impl Ticket {
    /// Block until the engine answers (or rejects) this request.
    pub fn wait(self) -> Result<Reply, Rejected> {
        match self.rx.recv() {
            Ok(reply) => reply,
            Err(_) => Err(Rejected::Closed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::jsonx::Json;

    #[test]
    fn rejected_wire_contract_is_stable() {
        // these (code, status) pairs are the published wire contract —
        // a change here breaks deployed network clients
        let cases = [
            (Rejected::Busy { depth: 3 }, "busy", 429),
            (Rejected::Deadline, "deadline", 504),
            (Rejected::Closed, "closed", 503),
        ];
        for (r, code, status) in cases {
            assert_eq!(r.code(), code);
            assert_eq!(r.status(), status);
        }
    }

    #[test]
    fn rejected_json_round_trips_and_carries_the_busy_hint() {
        for r in [
            Rejected::Busy { depth: 7 },
            Rejected::Busy { depth: 0 },
            Rejected::Busy { depth: 100_000 },
            Rejected::Deadline,
            Rejected::Closed,
        ] {
            let j = r.to_json();
            // in-process matchers survive the wire boundary
            assert_eq!(Rejected::from_json(&j).unwrap(), r);
            // the body re-parses from its own serialization
            let reparsed = Json::parse(&j.to_string()).unwrap();
            assert_eq!(Rejected::from_json(&reparsed).unwrap(), r);
            assert_eq!(
                reparsed.req("message").unwrap().as_str().unwrap(),
                r.to_string(),
                "Display strings stay the wire message"
            );
        }
        let busy = Rejected::Busy { depth: 7 }.to_json();
        let hint = busy.req("retry_after_ms").unwrap().as_f64().unwrap();
        assert_eq!(hint, 35.0, "5 ms per queued job");
        let floor = Rejected::Busy { depth: 0 }.to_json();
        assert_eq!(
            floor.req("retry_after_ms").unwrap().as_f64().unwrap(),
            10.0,
            "hint floor"
        );
        let ceil = Rejected::Busy { depth: 100_000 }.to_json();
        assert_eq!(
            ceil.req("retry_after_ms").unwrap().as_f64().unwrap(),
            1000.0,
            "hint ceiling"
        );
        assert!(Rejected::Deadline.to_json().get("retry_after_ms").is_none());
        assert!(Rejected::Deadline.retry_after().is_none());
    }

    #[test]
    fn rejected_from_json_fails_typed_on_garbage() {
        let bad = Json::parse(r#"{"code":"explode"}"#).unwrap();
        assert!(Rejected::from_json(&bad).is_err());
        let busy_no_depth = Json::parse(r#"{"code":"busy"}"#).unwrap();
        assert!(Rejected::from_json(&busy_no_depth).is_err());
        assert!(Rejected::from_json(&Json::Null).is_err());
    }
}
