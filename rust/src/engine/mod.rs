//! Unified serving engine: **one** construction path for every
//! deployment shape the MoPEQ system can serve.
//!
//! [`EngineBuilder`] composes the whole deployment declaratively —
//! model variant × [`WeightForm`] × [`PrecisionSource`] × backend ×
//! [`BatchPolicy`] × worker count × admission control — replacing the
//! old `ServerHandle::start` / `start_packed` and
//! `ModelExecutor::new` / `with_packed` constructor splits:
//!
//! ```no_run
//! use mopeq::engine::{Engine, PrecisionSource, WeightForm};
//! use mopeq::data::{gen_sample, Task};
//! use mopeq::rng::Rng;
//!
//! let engine = Engine::builder("dsvl2_tiny")
//!     .weight_form(WeightForm::Packed)
//!     .precision(PrecisionSource::Mopeq)
//!     .workers(2)
//!     .queue_depth(64)
//!     .build()?;
//! let client = engine.client();
//! let sample = gen_sample(Task::Blink, engine.config(), &mut Rng::new(0));
//! let reply = client.submit(sample)?.wait()?;
//! let live = engine.metrics(); // queryable while serving
//! let stats = engine.shutdown()?;
//! # Ok::<(), anyhow::Error>(())
//! ```
//!
//! **Topology.** N worker threads each own a backend `Session` and a
//! `ModelExecutor` replica; the immutable source stores (backbone
//! [`WeightStore`], packed [`PackedStore`]) are shared across workers
//! via `Arc`. A packed deployment's expert words stay shared all the
//! way into the executors (`Value::Packed` clones the `Arc`, no weight
//! bytes are copied), so scaling workers multiplies compute — not
//! packed expert memory. Requests flow through one bounded MPMC queue —
//! a full queue rejects the submit with a typed [`Rejected::Busy`]
//! (admission control), and a request whose per-client deadline expires
//! while queued is answered with [`Rejected::Deadline`] instead of
//! being served stale or dropped.

pub mod metrics;
pub(crate) mod queue;
mod worker;

pub use metrics::{MetricsSnapshot, WorkerSnapshot};

use crate::cluster::{assign_map, Granularity};
use crate::config::{self, ModelConfig, MIXED_BITS};
use crate::coordinator::{quantize_experts, Quantizer};
use crate::data::Sample;
use crate::importance::hessian_closed_form;
use crate::moe::{PackedStore, PrecisionMap, WeightStore};
use crate::serve::BatchPolicy;
use anyhow::{anyhow, bail, Result};
use metrics::Metrics;
use queue::JobQueue;
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

/// How the engine holds (and executes) expert weights.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum WeightForm {
    /// dense f32 reference weights, fp16-accounted — no quantization
    #[default]
    Fp16,
    /// quantize→dequantize: experts rounded through their assigned
    /// integer codes but served as dense f32 (the legacy qdq path)
    DequantizedF32,
    /// serve straight from bit-packed codes: no dense f32 expert copy
    /// is resident, and `MetricsSnapshot::resident` proves it
    Packed,
}

/// Where the per-expert precision map comes from.
#[derive(Clone, Debug, Default)]
pub enum PrecisionSource {
    /// fp16 reference — only valid with [`WeightForm::Fp16`]
    #[default]
    Reference,
    /// every expert at the same width
    Uniform(u8),
    /// a precomputed / loaded assignment
    Map(PrecisionMap),
    /// the paper's allocation: closed-form Hessian sensitivity →
    /// Algorithm 2 K-means over {2,3,4} bits, model-wise
    Mopeq,
}

/// Typed admission/deadline rejection — the only ways the engine
/// declines work.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Rejected {
    /// the bounded queue is at capacity; retry later or scale workers
    Busy { depth: usize },
    /// the request's deadline expired before a worker reached it
    Deadline,
    /// the engine is shutting down (or has shut down)
    Closed,
}

impl std::fmt::Display for Rejected {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Rejected::Busy { depth } => {
                write!(f, "engine busy: queue at depth {depth}")
            }
            Rejected::Deadline => write!(f, "request deadline expired"),
            Rejected::Closed => write!(f, "engine is shut down"),
        }
    }
}

impl std::error::Error for Rejected {}

/// Engine reply for one request.
#[derive(Clone, Debug)]
pub struct Reply {
    pub answer: usize,
    pub correct: bool,
    /// end-to-end latency (submit → reply)
    pub latency: Duration,
    /// how many real requests shared the executed batch (≥ 1)
    pub batch_fill: usize,
}

/// One admitted request, queued for a worker.
pub(crate) struct Job {
    pub sample: Sample,
    pub enqueued: Instant,
    pub deadline: Option<Instant>,
    pub respond: mpsc::Sender<Result<Reply, Rejected>>,
}

/// The shared immutable weights every worker replica executes over.
pub(crate) enum EngineWeights {
    Dense(Arc<WeightStore>),
    Packed {
        backbone: Arc<WeightStore>,
        experts: Arc<PackedStore>,
    },
}

impl EngineWeights {
    fn exec_weights(&self) -> crate::coordinator::ExecWeights<'_> {
        match self {
            EngineWeights::Dense(ws) => {
                crate::coordinator::ExecWeights::Dense(ws)
            }
            EngineWeights::Packed { backbone, experts } => {
                crate::coordinator::ExecWeights::Packed {
                    backbone,
                    experts,
                }
            }
        }
    }
}

pub(crate) struct Shared {
    pub(crate) queue: JobQueue,
    pub(crate) metrics: Metrics,
}

/// Builder for an [`Engine`] — the single construction path for every
/// deployment shape (see the module docs for the grammar).
pub struct EngineBuilder {
    variant: String,
    weights: Option<WeightStore>,
    seed: u64,
    form: WeightForm,
    precision: PrecisionSource,
    backend: Option<String>,
    policy: BatchPolicy,
    workers: usize,
    queue_depth: usize,
}

impl EngineBuilder {
    pub fn new(variant: impl Into<String>) -> EngineBuilder {
        EngineBuilder {
            variant: variant.into(),
            weights: None,
            seed: 0,
            form: WeightForm::Fp16,
            precision: PrecisionSource::Reference,
            backend: None,
            policy: BatchPolicy::default(),
            workers: 1,
            queue_depth: 128,
        }
    }

    /// Serve these weights (trained or reference). Without this the
    /// engine uses the variant's deterministic init at [`seed`](Self::seed).
    pub fn weights(mut self, ws: WeightStore) -> Self {
        self.weights = Some(ws);
        self
    }

    /// Seed for deterministic weight init (ignored when
    /// [`weights`](Self::weights) is given) and for Algorithm 2.
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn weight_form(mut self, form: WeightForm) -> Self {
        self.form = form;
        self
    }

    pub fn precision(mut self, src: PrecisionSource) -> Self {
        self.precision = src;
        self
    }

    /// Backend choice per worker: `"native"` or `"xla"`. Default
    /// follows `MOPEQ_BACKEND` (native when unset).
    pub fn backend(mut self, choice: impl Into<String>) -> Self {
        self.backend = Some(choice.into());
        self
    }

    pub fn batch_policy(mut self, policy: BatchPolicy) -> Self {
        self.policy = policy;
        self
    }

    /// Worker threads (≥ 1). Each owns a session + executor replica;
    /// expert weights are shared, so this scales compute not memory.
    pub fn workers(mut self, n: usize) -> Self {
        self.workers = n.max(1);
        self
    }

    /// Admission-control bound: jobs queued beyond this are rejected
    /// with [`Rejected::Busy`] instead of buffered.
    pub fn queue_depth(mut self, depth: usize) -> Self {
        self.queue_depth = depth.max(1);
        self
    }

    /// Resolve the deployment (assign → quantize/pack as the form
    /// demands), then spawn and warm the worker pool. Returns once
    /// every worker is ready to serve.
    pub fn build(self) -> Result<Engine> {
        let cfg = config::variant(&self.variant)?;
        let mut ws = match self.weights {
            Some(ws) => {
                if ws.variant != cfg.name {
                    bail!(
                        "weights are for `{}`, engine variant is `{}`",
                        ws.variant,
                        cfg.name
                    );
                }
                ws
            }
            None => WeightStore::init(&cfg, &crate::moe::local_meta(&cfg), self.seed),
        };

        let pmap = resolve_precision(&cfg, &ws, &self.precision, self.seed)?;
        let weights = match self.form {
            WeightForm::Fp16 => {
                if pmap.is_some() {
                    bail!(
                        "WeightForm::Fp16 serves the reference weights — \
                         use DequantizedF32 or Packed to apply a \
                         precision source"
                    );
                }
                EngineWeights::Dense(Arc::new(ws))
            }
            WeightForm::DequantizedF32 => {
                let pmap = pmap.clone().ok_or_else(|| {
                    anyhow!(
                        "WeightForm::DequantizedF32 needs a quantizing \
                         PrecisionSource (Uniform / Map / Mopeq)"
                    )
                })?;
                quantize_experts(None, &cfg, &mut ws, &pmap, &Quantizer::Rtn, None)?;
                EngineWeights::Dense(Arc::new(ws))
            }
            WeightForm::Packed => {
                let pmap = pmap.clone().ok_or_else(|| {
                    anyhow!(
                        "WeightForm::Packed needs a quantizing \
                         PrecisionSource (Uniform / Map / Mopeq)"
                    )
                })?;
                let store = PackedStore::rtn(&cfg, &ws, &pmap)?;
                ws.strip_experts();
                EngineWeights::Packed {
                    backbone: Arc::new(ws),
                    experts: Arc::new(store),
                }
            }
        };

        let weights = Arc::new(weights);
        let shared = Arc::new(Shared {
            queue: JobQueue::new(self.queue_depth),
            metrics: Metrics::new(self.workers),
        });
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let mut handles = Vec::with_capacity(self.workers);
        for index in 0..self.workers {
            let wc = worker::WorkerConfig {
                index,
                cfg: cfg.clone(),
                weights: weights.clone(),
                backend: self.backend.clone(),
                policy: self.policy,
                shared: shared.clone(),
            };
            let tx = ready_tx.clone();
            handles.push(
                std::thread::Builder::new()
                    .name(format!("mopeq-engine-{index}"))
                    .spawn(move || worker::run(wc, tx))?,
            );
        }
        drop(ready_tx);
        let mut first_err: Option<anyhow::Error> = None;
        for _ in 0..self.workers {
            let outcome = ready_rx
                .recv()
                .unwrap_or_else(|_| Err(anyhow!("a worker died during warmup")));
            if let Err(e) = outcome {
                if first_err.is_none() {
                    first_err = Some(e);
                }
            }
        }
        if let Some(e) = first_err {
            shared.queue.close();
            for h in handles {
                let _ = h.join();
            }
            return Err(e);
        }
        // every worker is warm: start the serving clock now so
        // throughput never includes compile/warmup cost
        shared.metrics.mark_started();
        Ok(Engine { shared, workers: handles, cfg, pmap })
    }
}

/// Resolve a [`PrecisionSource`] into the per-expert map it denotes
/// (`None` for the fp16 reference).
fn resolve_precision(
    cfg: &ModelConfig,
    ws: &WeightStore,
    src: &PrecisionSource,
    seed: u64,
) -> Result<Option<PrecisionMap>> {
    Ok(match src {
        PrecisionSource::Reference => None,
        PrecisionSource::Uniform(bits) => {
            if *bits >= 16 {
                bail!(
                    "PrecisionSource::Uniform({bits}) is the fp16 \
                     reference — use WeightForm::Fp16 with \
                     PrecisionSource::Reference"
                );
            }
            Some(PrecisionMap::uniform(cfg, *bits))
        }
        PrecisionSource::Map(pmap) => {
            if pmap.bits.len() != cfg.moe_layers()
                || pmap.bits.iter().any(|l| l.len() != cfg.experts)
            {
                bail!(
                    "precision map shape {}x{} != config {}x{}",
                    pmap.bits.len(),
                    pmap.bits.first().map_or(0, |l| l.len()),
                    cfg.moe_layers(),
                    cfg.experts
                );
            }
            Some(pmap.clone())
        }
        PrecisionSource::Mopeq => {
            let sens = hessian_closed_form(ws, cfg)?;
            Some(PrecisionMap {
                bits: assign_map(
                    &sens.values,
                    &MIXED_BITS,
                    Granularity::ModelWise,
                    seed,
                ),
            })
        }
    })
}

/// A running deployment: worker pool + shared queue + live metrics.
pub struct Engine {
    shared: Arc<Shared>,
    workers: Vec<std::thread::JoinHandle<Result<()>>>,
    cfg: ModelConfig,
    /// the resolved per-expert map this engine serves (None for fp16)
    pmap: Option<PrecisionMap>,
}

impl Engine {
    /// Start composing a deployment for a model variant.
    pub fn builder(variant: impl Into<String>) -> EngineBuilder {
        EngineBuilder::new(variant)
    }

    pub fn config(&self) -> &ModelConfig {
        &self.cfg
    }

    /// The precision map the engine resolved at build (None = fp16
    /// reference) — what `MetricsSnapshot::resident` accounting is
    /// checked against.
    pub fn precision_map(&self) -> Option<&PrecisionMap> {
        self.pmap.as_ref()
    }

    /// A cheap client session (an `Arc` clone). Clients are `Send` and
    /// independent — hand one to each request thread.
    pub fn client(&self) -> Client {
        Client { shared: self.shared.clone(), deadline: None }
    }

    /// Live telemetry — queryable **while serving**, not only at
    /// shutdown.
    pub fn metrics(&self) -> MetricsSnapshot {
        self.shared.metrics.snapshot(self.shared.queue.len())
    }

    /// Stop admissions, drain every queued job through the workers,
    /// join them, and return the final snapshot.
    pub fn shutdown(mut self) -> Result<MetricsSnapshot> {
        self.shared.queue.close();
        let mut first_err: Option<anyhow::Error> = None;
        for h in self.workers.drain(..) {
            let outcome = h
                .join()
                .map_err(|_| anyhow!("an engine worker panicked"))
                .and_then(|r| r);
            if let Err(e) = outcome {
                if first_err.is_none() {
                    first_err = Some(e);
                }
            }
        }
        if let Some(e) = first_err {
            return Err(e);
        }
        Ok(self.shared.metrics.snapshot(self.shared.queue.len()))
    }
}

impl Drop for Engine {
    /// An engine dropped without [`shutdown`](Engine::shutdown) (early
    /// `?` return, panic unwind) must not strand its worker threads
    /// blocked on an open queue forever: close, let them drain, join.
    fn drop(&mut self) {
        self.shared.queue.close();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

/// A typed client session over a running engine.
#[derive(Clone)]
pub struct Client {
    shared: Arc<Shared>,
    deadline: Option<Duration>,
}

impl Client {
    /// Per-request deadline: a request still queued when it expires is
    /// answered with [`Rejected::Deadline`].
    pub fn with_deadline(mut self, deadline: Duration) -> Client {
        self.deadline = Some(deadline);
        self
    }

    /// Submit a request past admission control. `Err(Busy)` when the
    /// bounded queue is full, `Err(Closed)` after shutdown.
    pub fn submit(&self, sample: Sample) -> Result<Ticket, Rejected> {
        let (tx, rx) = mpsc::channel();
        let now = Instant::now();
        let job = Job {
            sample,
            enqueued: now,
            deadline: self.deadline.map(|d| now + d),
            respond: tx,
        };
        // count the attempt *before* the push: once the job is visible
        // in the queue a worker may answer it, and a concurrent
        // snapshot must never read `requests > submitted`
        self.shared.metrics.count_submitted();
        match self.shared.queue.push(job) {
            Ok(()) => Ok(Ticket { rx }),
            Err(r) => {
                self.shared.metrics.uncount_submitted();
                if matches!(r, Rejected::Busy { .. }) {
                    self.shared.metrics.count_busy();
                }
                Err(r)
            }
        }
    }

    /// Submit and block for the reply.
    pub fn call(&self, sample: Sample) -> Result<Reply, Rejected> {
        self.submit(sample)?.wait()
    }
}

/// The pending reply for one submitted request.
pub struct Ticket {
    rx: mpsc::Receiver<Result<Reply, Rejected>>,
}

impl Ticket {
    /// Block until the engine answers (or rejects) this request.
    pub fn wait(self) -> Result<Reply, Rejected> {
        match self.rx.recv() {
            Ok(reply) => reply,
            Err(_) => Err(Rejected::Closed),
        }
    }
}
