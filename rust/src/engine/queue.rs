//! Bounded multi-producer/multi-consumer job queue: the admission-control
//! boundary between [`Client`](crate::engine::Client)s and the worker
//! pool. Depth is a hard cap — a full queue rejects the submit with a
//! typed [`Rejected::Busy`] instead of buffering unboundedly, which is
//! what lets the engine shed load with bounded tail latency instead of
//! collapsing under it (the vendor set has no tokio; a `Mutex` +
//! `Condvar` deque is the honest std topology for a handful of worker
//! threads).

use crate::engine::{Job, Rejected};
use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Condvar, Mutex};
use std::time::Instant;

/// What a swap-aware blocking pop yields.
pub(crate) enum Popped {
    Job(Job),
    /// the staged-weights generation advanced past the worker's —
    /// rebuild on the new weights before serving anything else
    Swap,
    /// closed **and** drained
    Closed,
}

struct QueueState {
    jobs: VecDeque<Job>,
    /// false once the engine begins shutdown: submits are rejected but
    /// queued jobs are still drained by the workers
    open: bool,
}

pub(crate) struct JobQueue {
    state: Mutex<QueueState>,
    notify: Condvar,
    depth: usize,
}

impl JobQueue {
    pub fn new(depth: usize) -> JobQueue {
        JobQueue {
            state: Mutex::new(QueueState {
                jobs: VecDeque::with_capacity(depth.max(1)),
                open: true,
            }),
            notify: Condvar::new(),
            depth: depth.max(1),
        }
    }

    /// Admit a job, or reject it without blocking: [`Rejected::Busy`]
    /// when the queue is at depth, [`Rejected::Closed`] after shutdown
    /// began.
    pub fn push(&self, job: Job) -> Result<(), Rejected> {
        let mut st = self.state.lock().unwrap();
        if !st.open {
            return Err(Rejected::Closed);
        }
        if st.jobs.len() >= self.depth {
            return Err(Rejected::Busy { depth: st.jobs.len() });
        }
        st.jobs.push_back(job);
        drop(st);
        self.notify.notify_one();
        Ok(())
    }

    /// Blocking pop: waits for a job; returns `None` only when the
    /// queue is closed **and** drained (the shutdown-drain guarantee —
    /// every admitted job is either executed or deadline-rejected, never
    /// silently dropped).
    pub fn pop(&self) -> Option<Job> {
        let mut st = self.state.lock().unwrap();
        loop {
            if let Some(job) = st.jobs.pop_front() {
                return Some(job);
            }
            if !st.open {
                return None;
            }
            st = self.notify.wait(st).unwrap();
        }
    }

    /// Swap-aware blocking pop — the worker's main loop. Returns
    /// [`Popped::Swap`] as soon as `generation` differs from the
    /// caller's `seen` value, **before** taking another job: a staged
    /// weight swap preempts queued work (the jobs stay queued and are
    /// served by the rebuilt executor, never dropped). The generation
    /// check lives inside the condvar loop, so an idle worker parked
    /// here is woken by [`JobQueue::nudge`] and observes the swap
    /// without a job ever arriving.
    pub fn pop_or_swap(&self, generation: &AtomicU64, seen: u64) -> Popped {
        let mut st = self.state.lock().unwrap();
        loop {
            if generation.load(Ordering::Acquire) != seen {
                return Popped::Swap;
            }
            if let Some(job) = st.jobs.pop_front() {
                return Popped::Job(job);
            }
            if !st.open {
                return Popped::Closed;
            }
            st = self.notify.wait(st).unwrap();
        }
    }

    /// Pop with a deadline (the batch-linger fill path): returns `None`
    /// when the deadline passes, or immediately when the queue is closed
    /// and drained — a draining worker never lingers on an empty queue.
    pub fn pop_before(&self, deadline: Instant) -> Option<Job> {
        let mut st = self.state.lock().unwrap();
        loop {
            if let Some(job) = st.jobs.pop_front() {
                return Some(job);
            }
            if !st.open {
                return None;
            }
            let now = Instant::now();
            if now >= deadline {
                return None;
            }
            let (guard, timeout) = self
                .notify
                .wait_timeout(st, deadline - now)
                .unwrap();
            st = guard;
            if timeout.timed_out() && st.jobs.is_empty() {
                return None;
            }
        }
    }

    /// Current queue occupancy (live `MetricsSnapshot.queue_depth`).
    pub fn len(&self) -> usize {
        self.state.lock().unwrap().jobs.len()
    }

    /// Whether submits are still admitted (false once shutdown began).
    pub fn is_open(&self) -> bool {
        self.state.lock().unwrap().open
    }

    /// Wake every parked worker without closing or enqueueing — the
    /// swap path's kick after staging a new generation. Taking the
    /// lock first means any worker that read the old generation is
    /// already inside `wait()` and receives the notification.
    pub fn nudge(&self) {
        drop(self.state.lock().unwrap());
        self.notify.notify_all();
    }

    /// Begin shutdown: reject new submits, wake every worker so the
    /// remaining jobs drain.
    pub fn close(&self) {
        self.state.lock().unwrap().open = false;
        self.notify.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config;
    use crate::data::{gen_sample, Task};
    use crate::rng::Rng;
    use std::sync::mpsc;
    use std::time::Duration;

    fn job() -> Job {
        let cfg = config::variant("dsvl2_tiny").unwrap();
        let mut rng = Rng::new(0);
        let (tx, _rx) = mpsc::channel();
        Job {
            sample: gen_sample(Task::Blink, &cfg, &mut rng),
            enqueued: Instant::now(),
            popped: None,
            deadline: None,
            respond: tx,
        }
    }

    #[test]
    fn depth_is_a_hard_cap_with_typed_busy() {
        let q = JobQueue::new(2);
        q.push(job()).unwrap();
        q.push(job()).unwrap();
        match q.push(job()) {
            Err(Rejected::Busy { depth }) => assert_eq!(depth, 2),
            other => panic!("expected Busy, got {other:?}"),
        }
        assert_eq!(q.len(), 2);
        // popping frees a slot
        q.pop().unwrap();
        q.push(job()).unwrap();
    }

    #[test]
    fn close_rejects_submits_but_drains_queued_jobs() {
        let q = JobQueue::new(4);
        q.push(job()).unwrap();
        q.push(job()).unwrap();
        q.close();
        assert!(matches!(q.push(job()), Err(Rejected::Closed)));
        assert!(q.pop().is_some());
        assert!(q.pop().is_some());
        assert!(q.pop().is_none(), "closed + drained must return None");
    }

    #[test]
    fn pop_or_swap_preempts_on_generation() {
        let q = JobQueue::new(4);
        let generation = AtomicU64::new(0);
        q.push(job()).unwrap();
        // generation unchanged → jobs come out as usual
        assert!(matches!(q.pop_or_swap(&generation, 0), Popped::Job(_)));
        // a staged generation preempts even a non-empty queue…
        q.push(job()).unwrap();
        generation.store(1, Ordering::Release);
        assert!(matches!(q.pop_or_swap(&generation, 0), Popped::Swap));
        // …and the queued job survives for the rebuilt worker
        assert!(matches!(q.pop_or_swap(&generation, 1), Popped::Job(_)));
        assert!(q.is_open());
        q.close();
        assert!(!q.is_open());
        assert!(matches!(q.pop_or_swap(&generation, 1), Popped::Closed));
    }

    #[test]
    fn nudge_wakes_an_idle_worker_into_the_swap() {
        use std::sync::Arc;
        let q = Arc::new(JobQueue::new(1));
        let generation = Arc::new(AtomicU64::new(0));
        let (q2, g2) = (Arc::clone(&q), Arc::clone(&generation));
        let h = std::thread::spawn(move || {
            matches!(q2.pop_or_swap(&g2, 0), Popped::Swap)
        });
        std::thread::sleep(Duration::from_millis(30));
        generation.store(1, Ordering::Release);
        q.nudge();
        assert!(h.join().unwrap(), "parked worker must see the swap");
    }

    #[test]
    fn pop_before_times_out_and_skips_linger_when_closed() {
        let q = JobQueue::new(1);
        let start = Instant::now();
        let deadline = start + Duration::from_millis(20);
        assert!(q.pop_before(deadline).is_none());
        assert!(start.elapsed() >= Duration::from_millis(20));
        q.close();
        let start = Instant::now();
        assert!(q.pop_before(start + Duration::from_secs(5)).is_none());
        assert!(
            start.elapsed() < Duration::from_secs(1),
            "a closed empty queue must not linger"
        );
    }
}
