//! Bounded multi-producer/multi-consumer job queue: the admission-control
//! boundary between [`Client`](crate::engine::Client)s and the worker
//! pool. Depth is a hard cap — a full queue rejects the submit with a
//! typed [`Rejected::Busy`] instead of buffering unboundedly, which is
//! what lets the engine shed load with bounded tail latency instead of
//! collapsing under it (the vendor set has no tokio; a `Mutex` +
//! `Condvar` deque is the honest std topology for a handful of worker
//! threads).

use crate::engine::{Job, Rejected};
use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::Instant;

struct QueueState {
    jobs: VecDeque<Job>,
    /// false once the engine begins shutdown: submits are rejected but
    /// queued jobs are still drained by the workers
    open: bool,
}

pub(crate) struct JobQueue {
    state: Mutex<QueueState>,
    notify: Condvar,
    depth: usize,
}

impl JobQueue {
    pub fn new(depth: usize) -> JobQueue {
        JobQueue {
            state: Mutex::new(QueueState {
                jobs: VecDeque::with_capacity(depth.max(1)),
                open: true,
            }),
            notify: Condvar::new(),
            depth: depth.max(1),
        }
    }

    /// Admit a job, or reject it without blocking: [`Rejected::Busy`]
    /// when the queue is at depth, [`Rejected::Closed`] after shutdown
    /// began.
    pub fn push(&self, job: Job) -> Result<(), Rejected> {
        let mut st = self.state.lock().unwrap();
        if !st.open {
            return Err(Rejected::Closed);
        }
        if st.jobs.len() >= self.depth {
            return Err(Rejected::Busy { depth: st.jobs.len() });
        }
        st.jobs.push_back(job);
        drop(st);
        self.notify.notify_one();
        Ok(())
    }

    /// Blocking pop: waits for a job; returns `None` only when the
    /// queue is closed **and** drained (the shutdown-drain guarantee —
    /// every admitted job is either executed or deadline-rejected, never
    /// silently dropped).
    pub fn pop(&self) -> Option<Job> {
        let mut st = self.state.lock().unwrap();
        loop {
            if let Some(job) = st.jobs.pop_front() {
                return Some(job);
            }
            if !st.open {
                return None;
            }
            st = self.notify.wait(st).unwrap();
        }
    }

    /// Pop with a deadline (the batch-linger fill path): returns `None`
    /// when the deadline passes, or immediately when the queue is closed
    /// and drained — a draining worker never lingers on an empty queue.
    pub fn pop_before(&self, deadline: Instant) -> Option<Job> {
        let mut st = self.state.lock().unwrap();
        loop {
            if let Some(job) = st.jobs.pop_front() {
                return Some(job);
            }
            if !st.open {
                return None;
            }
            let now = Instant::now();
            if now >= deadline {
                return None;
            }
            let (guard, timeout) = self
                .notify
                .wait_timeout(st, deadline - now)
                .unwrap();
            st = guard;
            if timeout.timed_out() && st.jobs.is_empty() {
                return None;
            }
        }
    }

    /// Current queue occupancy (live `MetricsSnapshot.queue_depth`).
    pub fn len(&self) -> usize {
        self.state.lock().unwrap().jobs.len()
    }

    /// Begin shutdown: reject new submits, wake every worker so the
    /// remaining jobs drain.
    pub fn close(&self) {
        self.state.lock().unwrap().open = false;
        self.notify.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config;
    use crate::data::{gen_sample, Task};
    use crate::rng::Rng;
    use std::sync::mpsc;
    use std::time::Duration;

    fn job() -> Job {
        let cfg = config::variant("dsvl2_tiny").unwrap();
        let mut rng = Rng::new(0);
        let (tx, _rx) = mpsc::channel();
        Job {
            sample: gen_sample(Task::Blink, &cfg, &mut rng),
            enqueued: Instant::now(),
            deadline: None,
            respond: tx,
        }
    }

    #[test]
    fn depth_is_a_hard_cap_with_typed_busy() {
        let q = JobQueue::new(2);
        q.push(job()).unwrap();
        q.push(job()).unwrap();
        match q.push(job()) {
            Err(Rejected::Busy { depth }) => assert_eq!(depth, 2),
            other => panic!("expected Busy, got {other:?}"),
        }
        assert_eq!(q.len(), 2);
        // popping frees a slot
        q.pop().unwrap();
        q.push(job()).unwrap();
    }

    #[test]
    fn close_rejects_submits_but_drains_queued_jobs() {
        let q = JobQueue::new(4);
        q.push(job()).unwrap();
        q.push(job()).unwrap();
        q.close();
        assert!(matches!(q.push(job()), Err(Rejected::Closed)));
        assert!(q.pop().is_some());
        assert!(q.pop().is_some());
        assert!(q.pop().is_none(), "closed + drained must return None");
    }

    #[test]
    fn pop_before_times_out_and_skips_linger_when_closed() {
        let q = JobQueue::new(1);
        let start = Instant::now();
        let deadline = start + Duration::from_millis(20);
        assert!(q.pop_before(deadline).is_none());
        assert!(start.elapsed() >= Duration::from_millis(20));
        q.close();
        let start = Instant::now();
        assert!(q.pop_before(start + Duration::from_secs(5)).is_none());
        assert!(
            start.elapsed() < Duration::from_secs(1),
            "a closed empty queue must not linger"
        );
    }
}
