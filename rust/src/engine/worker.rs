//! Engine worker: one thread owning one backend [`Session`] and one
//! [`ModelExecutor`] replica, pulling jobs from the shared bounded
//! queue. The immutable source stores are shared across workers via
//! `Arc`; for packed deployments the expert words stay shared into the
//! executors themselves (`Value::Packed` clones the `Arc`), so worker
//! count multiplies compute, not packed expert memory. Sessions are
//! per-worker because backend state (call counters, compiled
//! executables) is not synchronized.

use crate::config::ModelConfig;
use crate::coordinator::executor::ModelExecutor;
use crate::data::Sample;
use crate::engine::queue::Popped;
use crate::engine::{EngineWeights, Job, Rejected, Reply, Shared};
use crate::obs::quality::{ProbeJob, QualityTap};
use crate::obs::trace::TraceSpan;
use crate::runtime::Session;
use crate::serve::{BatchPolicy, Batcher};
use anyhow::Result;
use std::sync::atomic::Ordering;
use std::sync::{mpsc, Arc};
use std::time::Instant;

pub(crate) struct WorkerConfig {
    pub index: usize,
    pub cfg: ModelConfig,
    pub weights: Arc<EngineWeights>,
    pub backend: Option<String>,
    pub policy: BatchPolicy,
    pub shared: Arc<Shared>,
    /// shadow-probe hand-off (`--quality-sample`): sampled completed
    /// requests go to the probe thread via a never-blocking `try_send`
    pub quality: Option<QualityTap>,
}

/// Why one executor's serve phase ended.
enum LoopExit {
    /// queue closed **and** drained — the worker is done
    Closed,
    /// a staged weight generation preempted serving — rebuild and
    /// resume
    Swap,
}

/// Worker body: open a session, build + warm the executor replica,
/// report readiness, then serve until the queue is closed **and**
/// drained. A staged hot-swap re-enters the build step: the worker
/// rebuilds its replica on the staged weights at a request boundary,
/// acknowledges the generation, and keeps serving — jobs queued across
/// the rebuild are served by the new weights, never dropped.
pub(crate) fn run(wc: WorkerConfig, ready: mpsc::Sender<Result<()>>) -> Result<()> {
    let session = match open_session(wc.backend.as_deref()) {
        Ok(s) => s,
        Err(e) => {
            let msg = format!("{e}");
            let _ = ready.send(Err(e));
            anyhow::bail!("worker {}: session open failed: {msg}", wc.index);
        }
    };
    // a failure past this point — Err *or panic* — must not strand
    // callers: the guard stops admissions and rejects whatever is still
    // queued so no Ticket::wait blocks forever on a queue nobody will
    // drain (healthy workers of a multi-worker pool may still race the
    // drain for some of these jobs — those get served, the rest get a
    // typed rejection). Disarmed on the clean exit path.
    let mut guard = FailGuard { shared: wc.shared.as_ref(), armed: true };
    let mut weights = wc.weights.clone();
    let mut generation =
        wc.shared.swap.generation.load(Ordering::Acquire);
    let mut announced = false;
    let result = loop {
        let exec = match ModelExecutor::with_weights(
            &session,
            &wc.cfg,
            weights.exec_weights(),
        )
        .and_then(|ex| ex.warm().map(|_| ex))
        {
            Ok(ex) => ex,
            Err(e) => {
                if announced {
                    // a mid-swap rebuild failure: the guard drains
                    break Err(e);
                }
                let msg = format!("{e}");
                let _ = ready.send(Err(e));
                break Err(anyhow::anyhow!(
                    "worker {}: executor build failed: {msg}",
                    wc.index
                ));
            }
        };
        wc.shared.metrics.set_resident(exec.resident_report());
        if !announced {
            let _ = ready.send(Ok(()));
            announced = true;
        }
        // acknowledge only after the replica is built and warm: a
        // reload returns when every ack reaches its generation, and
        // from that point every reply must come from the new weights
        wc.shared.swap.acks[wc.index]
            .store(generation, Ordering::Release);
        match serve_loop(&wc, &exec, generation) {
            Err(e) => break Err(e),
            Ok(LoopExit::Closed) => break Ok(()),
            Ok(LoopExit::Swap) => {
                drop(exec);
                // load the generation BEFORE cloning the staged slot:
                // stage happens-before bump, so the clone is at least
                // as new as the generation acknowledged for it (a
                // racing second swap costs one harmless extra rebuild,
                // never a stale ack)
                generation =
                    wc.shared.swap.generation.load(Ordering::Acquire);
                if let Some(w) =
                    wc.shared.swap.staged.lock().unwrap().clone()
                {
                    weights = w;
                }
            }
        }
    };
    if result.is_ok() {
        guard.armed = false;
    }
    drop(guard);
    result
}

/// Drop guard for the worker's serve phase: on an error return or a
/// panic unwind it closes the queue and drains it with typed
/// rejections (serve_loop panics happen outside the queue's mutex, so
/// its lock is not poisoned here).
struct FailGuard<'a> {
    shared: &'a Shared,
    armed: bool,
}

impl Drop for FailGuard<'_> {
    fn drop(&mut self) {
        if !self.armed {
            return;
        }
        self.shared.queue.close();
        while let Some(job) = self.shared.queue.pop() {
            let _ = job.respond.send(Err(Rejected::Closed));
        }
    }
}

fn serve_loop(
    wc: &WorkerConfig,
    exec: &ModelExecutor,
    generation: u64,
) -> Result<LoopExit> {
    let mut batcher: Batcher<Job> = Batcher::new(wc.policy, wc.cfg.batch);
    loop {
        let mut first = match wc
            .shared
            .queue
            .pop_or_swap(&wc.shared.swap.generation, generation)
        {
            Popped::Job(job) => job,
            Popped::Swap => return Ok(LoopExit::Swap),
            Popped::Closed => return Ok(LoopExit::Closed),
        };
        first.popped = Some(Instant::now());
        if batcher.push(first).is_err() {
            // flush() drains the batcher before every loop iteration,
            // and the fill loop below is guarded by !full() — a reject
            // here means a job would vanish without a reply, so fail
            // loudly instead of dropping it silently
            unreachable!("batcher not drained at loop top");
        }
        let linger = Instant::now() + wc.policy.max_linger;
        while !batcher.full() {
            match wc.shared.queue.pop_before(linger) {
                Some(mut job) => {
                    job.popped = Some(Instant::now());
                    if batcher.push(job).is_err() {
                        unreachable!("push is guarded by !batcher.full()");
                    }
                }
                None => break,
            }
        }
        flush(wc, exec, &mut batcher, generation)?;
    }
}

/// Backend selection shared by the workers and the builder's
/// resolution stage (calibration capture / profiling runs use the same
/// backend the workers will serve on).
pub(crate) fn open_session(choice: Option<&str>) -> Result<Session> {
    match choice {
        Some(c) => Session::from_choice(c),
        None => Session::open_default(),
    }
}

/// Execute the pending batch: deadline-expired jobs are rejected with a
/// typed reply (never silently dropped), the rest run as one static
/// batch and every reply carries the batch's real occupancy. Along the
/// way the batch feeds the observability plane: its per-expert routing
/// counts fold into the shared atomic histogram, and every served job
/// pushes a [`TraceSpan`] whose stages are disjoint sub-intervals of
/// its end-to-end window (so their sum can never exceed `total`).
fn flush(
    wc: &WorkerConfig,
    exec: &ModelExecutor,
    batcher: &mut Batcher<Job>,
    generation: u64,
) -> Result<()> {
    let triage_start = Instant::now();
    let (live, expired): (Vec<Job>, Vec<Job>) = batcher
        .take()
        .into_iter()
        .partition(|j| j.deadline.is_none_or(|d| triage_start < d));
    for job in expired {
        wc.shared.metrics.count_deadline();
        let _ = job.respond.send(Err(Rejected::Deadline));
    }
    if live.is_empty() {
        return Ok(());
    }
    let samples: Vec<Sample> = live.iter().map(|j| j.sample.clone()).collect();
    let (tokens, vis) = crate::data::pack_batch(&samples, &wc.cfg);
    let triage_done = Instant::now();
    let out = exec.forward(&tokens, &vis, false)?;
    let exec_done = Instant::now();
    // fold this batch's routing telemetry into the live histogram —
    // relaxed atomic adds into the preallocated grid, no allocation
    wc.shared.routing.record(&out.counts, tokens.len(), live.len());
    let preds = out.logits.argmax_rows();
    let fill = live.len();
    let latencies: Vec<_> =
        live.iter().map(|j| j.enqueued.elapsed()).collect();
    // record before replying so a client holding its reply is always
    // already visible in a metrics snapshot (requests == Σ fills holds
    // at every observable instant)
    wc.shared.metrics.record_batch(wc.index, fill, &latencies);
    for (i, ((job, &answer), latency)) in live
        .into_iter()
        .zip(preds.iter())
        .zip(latencies)
        .enumerate()
    {
        let send_start = Instant::now();
        let _ = job.respond.send(Ok(Reply {
            answer,
            correct: answer == job.sample.answer as usize,
            latency,
            batch_fill: fill,
        }));
        // the reply is on its way — only now consider shadow-probing
        // this request, and only through a never-blocking try_send
        if let Some(tap) = &wc.quality {
            if tap.sampled() {
                tap.send(ProbeJob {
                    sample: samples[i].clone(),
                    logits: out.logits.index0(i).data,
                    pred: answer,
                    generation,
                });
            }
        }
        // trace stage boundaries: enqueued ≤ popped ≤ triage_start ≤
        // triage_done ≤ exec_done ≤ send_start ≤ now. triage/execute
        // are batch-shared; queue_wait/linger/reply_send are per-job.
        let popped = job.popped.unwrap_or(triage_start);
        wc.shared.traces.push(TraceSpan {
            worker: wc.index,
            batch_fill: fill,
            start_ns: job
                .enqueued
                .saturating_duration_since(wc.shared.epoch)
                .as_nanos() as u64,
            queue_wait: popped.saturating_duration_since(job.enqueued),
            linger: triage_start.saturating_duration_since(popped),
            triage: triage_done.saturating_duration_since(triage_start),
            execute: exec_done.saturating_duration_since(triage_done),
            reply_send: send_start.elapsed(),
            total: job.enqueued.elapsed(),
        });
    }
    Ok(())
}
