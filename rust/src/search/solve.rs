//! Allocation solvers over the per-expert cost table: an **exact**
//! multiple-choice knapsack DP (GEMQ frames expert precision assignment
//! as a global budgeted optimization — this solves that optimization
//! to optimality at sim scale) and a marginal-cost local-search refiner
//! that walks the error-per-bit frontier from any feasible starting
//! assignment (in particular, from the greedy `cluster::enforce_budget`
//! result, which it therefore can never score worse than).
//!
//! All solvers speak the same language: `cost[i][p]` is the scalar
//! objective of giving flattened expert `i` palette choice `p`, and
//! `widths[p]` its bit price; the budget is a cap on the summed bits.

use crate::search::SearchError;
use anyhow::Result;

/// Total objective of an assignment (`assign[i]` = palette index).
pub fn score(assign: &[usize], cost: &[Vec<f64>]) -> f64 {
    assign.iter().zip(cost).map(|(&p, row)| row[p]).sum()
}

/// Total bits of an assignment.
pub fn total_bits(assign: &[usize], widths: &[u8]) -> usize {
    assign.iter().map(|&p| widths[p] as usize).sum()
}

/// Exact DP over per-expert palette choices: minimize
/// `Σ cost[i][assign[i]]` subject to `Σ widths[assign[i]] ≤ cap_bits`.
///
/// Classic multiple-choice knapsack on the bit budget — `O(n · cap ·
/// |palette|)` time, `O(n · cap)` choice memory: at sim scale (≤ ~2k
/// experts × ≤ 8 bits each) that is a few MB and milliseconds. Returns
/// a typed [`SearchError::InfeasibleBits`] when even the all-minimum
/// assignment exceeds the cap.
pub fn dp_solve(
    cost: &[Vec<f64>],
    widths: &[u8],
    cap_bits: usize,
) -> Result<Vec<usize>> {
    let n = cost.len();
    assert!(!widths.is_empty(), "empty palette");
    let min_w = *widths.iter().min().unwrap() as usize;
    if n * min_w > cap_bits {
        return Err(SearchError::InfeasibleBits {
            cap_bits,
            floor_bits: n * min_w,
        }
        .into());
    }
    // beyond all-maximum-width the budget cannot bind — clamp so a
    // generous byte budget sizes the DP table by the model, not the
    // budget (an unclamped multi-GB cap would OOM, not solve)
    let max_w = *widths.iter().max().unwrap() as usize;
    let cap_bits = cap_bits.min(n * max_w);
    // dp[b] = min cost with the experts so far summing to exactly b bits
    let mut dp = vec![f64::INFINITY; cap_bits + 1];
    dp[0] = 0.0;
    // choice[i][b] = palette index chosen for expert i when its prefix
    // lands on b total bits
    let mut choice = vec![u8::MAX; n * (cap_bits + 1)];
    let mut next = vec![f64::INFINITY; cap_bits + 1];
    for (i, row) in cost.iter().enumerate() {
        debug_assert_eq!(row.len(), widths.len());
        next.iter_mut().for_each(|v| *v = f64::INFINITY);
        let ch = &mut choice[i * (cap_bits + 1)..(i + 1) * (cap_bits + 1)];
        for (b, &base) in dp.iter().enumerate() {
            if !base.is_finite() {
                continue;
            }
            for (p, &w) in widths.iter().enumerate() {
                let nb = b + w as usize;
                if nb > cap_bits {
                    continue;
                }
                let c = base + row[p];
                if c < next[nb] {
                    next[nb] = c;
                    ch[nb] = p as u8;
                }
            }
        }
        std::mem::swap(&mut dp, &mut next);
    }
    // best endpoint ≤ cap, then backtrack through the choice table
    let mut best_b = 0;
    let mut best_c = f64::INFINITY;
    for (b, &c) in dp.iter().enumerate() {
        if c < best_c {
            best_c = c;
            best_b = b;
        }
    }
    debug_assert!(best_c.is_finite(), "feasible cap with no DP endpoint");
    let mut assign = vec![0usize; n];
    let mut b = best_b;
    for i in (0..n).rev() {
        let p = choice[i * (cap_bits + 1) + b] as usize;
        debug_assert!(p < widths.len(), "broken DP backtrack");
        assign[i] = p;
        b -= widths[p] as usize;
    }
    debug_assert_eq!(b, 0);
    Ok(assign)
}

/// Local-search refiner: walk the marginal cost-per-bit frontier from a
/// feasible assignment, applying the best single-expert move (one
/// palette step up or down) or paired move (one expert up a step, one
/// down a step) while the objective strictly improves and the bit cap
/// holds. Monotone — every accepted move lowers the objective — so a
/// refined greedy assignment **never** scores worse than greedy on the
/// same objective. Returns the number of moves applied.
pub fn refine(
    assign: &mut [usize],
    cost: &[Vec<f64>],
    widths: &[u8],
    cap_bits: usize,
) -> usize {
    let n = assign.len();
    let np = widths.len();
    if n == 0 || np < 2 {
        return 0;
    }
    let mut bits = total_bits(assign, widths);
    let mut moves = 0usize;
    // each accepted move strictly lowers a bounded objective; the cap
    // still bounds iterations defensively against float-noise cycles
    let max_moves = 4 * n * np + 64;
    while moves < max_moves {
        // best single move: expert e one palette step up or down
        let mut best: Option<(f64, usize, usize)> = None; // (Δcost, e, p)
        for (e, &cur) in assign.iter().enumerate() {
            for p in [cur.wrapping_sub(1), cur + 1] {
                if p >= np {
                    continue;
                }
                let delta_bits =
                    widths[p] as isize - widths[cur] as isize;
                if bits as isize + delta_bits > cap_bits as isize {
                    continue;
                }
                let delta = cost[e][p] - cost[e][cur];
                if delta < -1e-15
                    && best.is_none_or(|(bd, _, _)| delta < bd)
                {
                    best = Some((delta, e, p));
                }
            }
        }
        // paired move: the best one-step upgrade funded by the cheapest
        // one-step downgrade on another expert (lets error flow from
        // unimportant experts to important ones at constant budget)
        let mut up_best: Option<(f64, usize)> = None; // gain of +1 step
        let mut down_best: Option<(f64, usize)> = None; // pain of -1 step
        for (e, &cur) in assign.iter().enumerate() {
            if cur + 1 < np {
                let d = cost[e][cur + 1] - cost[e][cur];
                if up_best.is_none_or(|(bd, _)| d < bd) {
                    up_best = Some((d, e));
                }
            }
            if cur > 0 {
                let d = cost[e][cur - 1] - cost[e][cur];
                if down_best.is_none_or(|(bd, _)| d < bd) {
                    down_best = Some((d, e));
                }
            }
        }
        let mut pair: Option<(f64, usize, usize)> = None; // (Δ, up_e, down_e)
        if let (Some((ud, ue)), Some((dd, de))) = (up_best, down_best) {
            if ue != de {
                let up_bits = widths[assign[ue] + 1] as isize
                    - widths[assign[ue]] as isize;
                let down_bits = widths[assign[de] - 1] as isize
                    - widths[assign[de]] as isize;
                if bits as isize + up_bits + down_bits
                    <= cap_bits as isize
                {
                    let delta = ud + dd;
                    if delta < -1e-15 {
                        pair = Some((delta, ue, de));
                    }
                }
            }
        }
        // apply the better of the two move kinds, or stop at a local
        // optimum
        match (best, pair) {
            (Some((sd, _, _)), Some((pd, ue, de))) if pd < sd => {
                assign[ue] += 1;
                assign[de] -= 1;
            }
            (Some((_, e, p)), _) => {
                assign[e] = p;
            }
            (None, Some((_, ue, de))) => {
                assign[ue] += 1;
                assign[de] -= 1;
            }
            (None, None) => break,
        }
        bits = total_bits(assign, widths);
        debug_assert!(bits <= cap_bits);
        moves += 1;
    }
    moves
}

/// Map a width assignment (e.g. the greedy `cluster` output) onto
/// palette indices for scoring against the same cost table. Widths off
/// the palette yield a typed error — the solvers cannot price them.
pub fn widths_to_indices(
    bits: &[Vec<u8>],
    widths: &[u8],
) -> Result<Vec<usize>> {
    let mut out = Vec::with_capacity(bits.iter().map(Vec::len).sum());
    for row in bits {
        for &b in row {
            match widths.iter().position(|&w| w == b) {
                Some(p) => out.push(p),
                None => {
                    return Err(SearchError::OffPaletteWidth { bits: b }
                        .into())
                }
            }
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proptest_lite::forall;

    /// Synthetic cost rows: importance × an RTN-like error curve that
    /// shrinks ~4x per extra bit.
    fn cost_rows(importance: &[f64], widths: &[u8]) -> Vec<Vec<f64>> {
        importance
            .iter()
            .map(|imp| {
                widths
                    .iter()
                    .map(|&w| imp * 0.25f64.powi(w as i32))
                    .collect()
            })
            .collect()
    }

    #[test]
    fn dp_gives_high_bits_to_important_experts() {
        let widths = [2u8, 3, 4];
        let cost = cost_rows(&[1.0, 100.0, 1.0, 100.0], &widths);
        // cap 12 = mean 3.0: the optimum is {2,4,2,4}
        let assign = dp_solve(&cost, &widths, 12).unwrap();
        assert_eq!(assign, vec![0, 2, 0, 2]);
        assert_eq!(total_bits(&assign, &widths), 12);
    }

    #[test]
    fn dp_uses_slack_when_error_still_falls() {
        let widths = [2u8, 3, 4];
        let cost = cost_rows(&[1.0, 1.0], &widths);
        // cap 8 = everyone at max width: error is monotone in bits, so
        // the optimum spends the whole budget
        let assign = dp_solve(&cost, &widths, 8).unwrap();
        assert_eq!(assign, vec![2, 2]);
    }

    #[test]
    fn dp_clamps_non_binding_caps_to_the_model_size() {
        // a cap far beyond all-max-width must solve instantly (table
        // sized by the model), not allocate a budget-sized DP table
        let widths = [2u8, 3, 4];
        let cost = cost_rows(&[1.0, 2.0, 3.0], &widths);
        let assign = dp_solve(&cost, &widths, usize::MAX / 2).unwrap();
        assert_eq!(assign, vec![2, 2, 2]);
    }

    #[test]
    fn dp_infeasible_cap_is_a_typed_error() {
        let widths = [2u8, 3, 4];
        let cost = cost_rows(&[1.0, 1.0, 1.0], &widths);
        let err = dp_solve(&cost, &widths, 5).unwrap_err();
        assert_eq!(
            err.downcast_ref::<SearchError>(),
            Some(&SearchError::InfeasibleBits {
                cap_bits: 5,
                floor_bits: 6
            })
        );
    }

    #[test]
    fn refine_only_improves_and_respects_the_cap() {
        forall("refine_improves", 30, |rng| {
            let widths = [2u8, 3, 4];
            let n = 3 + rng.below(12);
            let importance: Vec<f64> =
                (0..n).map(|_| rng.uniform() * 10.0).collect();
            let cost = cost_rows(&importance, &widths);
            let cap = n * 2 + rng.below(n * 2 + 1);
            // random feasible start: everyone at the floor, then pad
            let mut assign = vec![0usize; n];
            let before_feasible = total_bits(&assign, &widths) <= cap;
            let before = score(&assign, &cost);
            refine(&mut assign, &cost, &widths, cap);
            let after = score(&assign, &cost);
            before_feasible
                && after <= before + 1e-12
                && total_bits(&assign, &widths) <= cap
        });
    }

    #[test]
    fn refine_reaches_the_dp_optimum_on_small_instances() {
        // with a planted two-tier skew and single/paired one-step moves,
        // the refiner climbs from all-floor to the DP optimum
        let widths = [2u8, 3, 4];
        let cost = cost_rows(&[50.0, 1.0, 50.0, 1.0], &widths);
        let cap = 12;
        let dp = dp_solve(&cost, &widths, cap).unwrap();
        let mut assign = vec![0usize; 4];
        refine(&mut assign, &cost, &widths, cap);
        assert_eq!(score(&assign, &cost), score(&dp, &cost));
        assert_eq!(assign, vec![2, 0, 2, 0]);
    }

    #[test]
    fn dp_is_optimal_vs_exhaustive_enumeration() {
        forall("dp_vs_bruteforce", 25, |rng| {
            let widths = [2u8, 3, 4];
            let n = 2 + rng.below(5); // 3^6 = 729 states max
            let importance: Vec<f64> =
                (0..n).map(|_| rng.uniform() * 5.0).collect();
            let cost = cost_rows(&importance, &widths);
            let cap = n * 2 + rng.below(n * 2 + 1);
            let dp = dp_solve(&cost, &widths, cap).unwrap();
            // brute force over all palette combinations
            let mut best = f64::INFINITY;
            let states = widths.len().pow(n as u32);
            for s in 0..states {
                let mut x = s;
                let mut a = Vec::with_capacity(n);
                for _ in 0..n {
                    a.push(x % widths.len());
                    x /= widths.len();
                }
                if total_bits(&a, &widths) <= cap {
                    best = best.min(score(&a, &cost));
                }
            }
            (score(&dp, &cost) - best).abs() < 1e-9
        });
    }

    #[test]
    fn widths_to_indices_rejects_off_palette() {
        let ok = widths_to_indices(&[vec![2, 4], vec![3, 3]], &[2, 3, 4])
            .unwrap();
        assert_eq!(ok, vec![0, 2, 1, 1]);
        let err =
            widths_to_indices(&[vec![2, 16]], &[2, 3, 4]).unwrap_err();
        assert_eq!(
            err.downcast_ref::<SearchError>(),
            Some(&SearchError::OffPaletteWidth { bits: 16 })
        );
    }
}
