//! Packed-kernel throughput profile — the MxMoE-style efficiency side
//! of the allocation search. Accuracy-only allocation treats every bit
//! width as equally servable, but the fused `qmatmul{2,3,4,8}` kernels
//! read weight bytes at *different* effective rates (the 3-bit layout
//! wastes 2 bits per u32 word and pays a wider unpack shift), so a
//! palette choice has a throughput price the [`crate::search::CostModel`]
//! must see.
//!
//! The profile is either the built-in table below (representative host
//! measurements from the `quant_throughput` bench) or a **measured**
//! profile loaded from the machine-readable `BENCH_quant_throughput.json`
//! that bench emits — so a deployment searched on the serving machine is
//! weighed by that machine's actual kernel rates.

use crate::config::ModelConfig;
use crate::jsonx::Json;
use crate::quant::pack;
use crate::search::SearchError;
use anyhow::Result;
use std::path::Path;

/// Weight-read throughput of the packed qmatmul kernel per bit width,
/// in GB/s over the *resident heap bytes* the kernel actually streams
/// (u32 words + f32 scale/zp vectors).
#[derive(Clone, Debug, PartialEq)]
pub struct ThroughputProfile {
    /// `(bits, GB/s)`, ascending by bits
    pub gbs: Vec<(u8, f64)>,
    /// `"builtin"` or the path of the bench JSON it was loaded from
    pub source: String,
}

impl Default for ThroughputProfile {
    fn default() -> Self {
        ThroughputProfile::builtin()
    }
}

impl ThroughputProfile {
    /// The built-in table: representative host rates from the
    /// `quant_throughput` bench's fused-qmatmul section. The *shape* is
    /// what the search needs — 3-bit is the least byte-efficient width
    /// (10 codes per u32, 2 padding bits, non-power-of-two shifts),
    /// 8-bit streams fastest — absolute numbers are machine-dependent
    /// and a measured profile should replace them
    /// ([`ThroughputProfile::from_bench_json`]).
    pub fn builtin() -> ThroughputProfile {
        ThroughputProfile {
            gbs: vec![(2, 2.4), (3, 1.6), (4, 2.8), (8, 4.5)],
            source: "builtin".into(),
        }
    }

    /// GB/s for one bit width, if profiled.
    pub fn gbs_for(&self, bits: u8) -> Option<f64> {
        self.gbs.iter().find(|&&(b, _)| b == bits).map(|&(_, g)| g)
    }

    /// Typed check that every palette width has a profile entry — a
    /// width the profile cannot price would make the throughput term
    /// silently wrong.
    pub fn check_palette(&self, palette: &[u8]) -> Result<()> {
        for &bits in palette {
            if self.gbs_for(bits).is_none() {
                return Err(SearchError::NoProfileEntry { bits }.into());
            }
        }
        Ok(())
    }

    /// Load a measured profile from the `BENCH_quant_throughput.json`
    /// artifact (`benchx::BenchLog` schema: a `"qmatmul"` object keyed
    /// by bit width, each entry carrying a `"gbs"` number). Malformed
    /// artifacts fail with a typed [`SearchError::Profile`].
    pub fn from_bench_json(path: &Path) -> Result<ThroughputProfile> {
        let bad = |detail: String| SearchError::Profile {
            path: path.display().to_string(),
            detail,
        };
        let text = std::fs::read_to_string(path)
            .map_err(|e| bad(format!("read: {e}")))?;
        let json = Json::parse(&text)
            .map_err(|e| bad(format!("parse: {e}")))?;
        let qm = json
            .get("qmatmul")
            .ok_or_else(|| bad("missing `qmatmul` object".into()))?;
        let mut gbs = Vec::new();
        for (key, entry) in
            qm.as_obj().map_err(|e| bad(format!("qmatmul: {e}")))?
        {
            let bits: u8 = key.parse().map_err(|_| {
                bad(format!("qmatmul key `{key}` is not a bit width"))
            })?;
            let g = entry
                .get("gbs")
                .ok_or_else(|| bad(format!("qmatmul.{key}: missing `gbs`")))?
                .as_f64()
                .map_err(|e| bad(format!("qmatmul.{key}.gbs: {e}")))?;
            if !(g.is_finite() && g > 0.0) {
                return Err(bad(format!(
                    "qmatmul.{key}.gbs = {g} is not a positive rate"
                ))
                .into());
            }
            gbs.push((bits, g));
        }
        if gbs.is_empty() {
            return Err(bad("`qmatmul` object has no width entries".into())
                .into());
        }
        gbs.sort_by_key(|&(b, _)| b);
        Ok(ThroughputProfile { gbs, source: path.display().to_string() })
    }

    /// Predicted wall time, in µs, to stream one routed expert's packed
    /// weights at `bits` through the profiled kernel.
    pub fn expert_read_us(&self, cfg: &ModelConfig, bits: u8) -> Result<f64> {
        let gbs = self.gbs_for(bits).ok_or_else(|| {
            anyhow::Error::new(SearchError::NoProfileEntry { bits })
        })?;
        Ok(packed_expert_heap_bytes(cfg, bits) as f64 / (gbs * 1e3))
    }
}

/// Resident heap bytes of one packed FC matrix: u32 words (including
/// the 3-bit padding and ragged-tail waste the kernel actually reads)
/// plus the f32 scale/zp vectors — mirrors
/// `quant::kernels::PackedMatrix::heap_bytes` without materializing one.
fn packed_matrix_heap_bytes(din: usize, dout: usize, bits: u8, group: usize) -> usize {
    let grp = if group > 0 && din % group == 0 { group } else { din };
    let groups = din / grp.max(1);
    pack::words_per_col(din, bits) * dout * 4 + 2 * groups * dout * 4
}

/// Resident heap bytes of one routed expert (gate + up + down) at
/// `bits` — the byte count the throughput term charges, as opposed to
/// the *wire* bytes `moe::expert_size_bits` accounts (heap ≥ wire: u32
/// padding is a real read cost but not a storage cost).
pub fn packed_expert_heap_bytes(cfg: &ModelConfig, bits: u8) -> usize {
    let (d, m, g) = (cfg.d_model, cfg.d_expert, cfg.group);
    2 * packed_matrix_heap_bytes(d, m, bits, g)
        + packed_matrix_heap_bytes(m, d, bits, g)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config;
    use crate::quant::kernels::PackedMatrix;
    use crate::quant::rtn_quantize;
    use crate::rng::Rng;
    use crate::tensor::Tensor;

    #[test]
    fn builtin_covers_the_packable_widths() {
        let p = ThroughputProfile::builtin();
        for bits in [2u8, 3, 4, 8] {
            assert!(p.gbs_for(bits).unwrap() > 0.0);
        }
        assert!(p.gbs_for(5).is_none());
        p.check_palette(&[2, 3, 4]).unwrap();
        let err = p.check_palette(&[2, 5]).unwrap_err();
        assert_eq!(
            err.downcast_ref::<SearchError>(),
            Some(&SearchError::NoProfileEntry { bits: 5 })
        );
    }

    #[test]
    fn three_bit_is_the_least_byte_efficient_width() {
        // the MxMoE motivation: the built-in shape must keep the 3-bit
        // padding penalty visible to the solver
        let p = ThroughputProfile::builtin();
        assert!(p.gbs_for(3).unwrap() < p.gbs_for(2).unwrap());
        assert!(p.gbs_for(3).unwrap() < p.gbs_for(4).unwrap());
        assert!(p.gbs_for(8).unwrap() > p.gbs_for(4).unwrap());
    }

    #[test]
    fn heap_bytes_formula_matches_a_real_packed_matrix() {
        let cfg = config::variant("dsvl2_tiny").unwrap();
        let mut rng = Rng::new(0);
        for bits in [2u8, 3, 4, 8] {
            // gate/up shape [d, m] and down shape [m, d]
            let gate = Tensor::randn(
                &mut rng,
                &[cfg.d_model, cfg.d_expert],
                0.5,
            );
            let down = Tensor::randn(
                &mut rng,
                &[cfg.d_expert, cfg.d_model],
                0.5,
            );
            let pm_gate = PackedMatrix::from_quantized(&rtn_quantize(
                &gate, bits, cfg.group,
            ))
            .unwrap();
            let pm_down = PackedMatrix::from_quantized(&rtn_quantize(
                &down, bits, cfg.group,
            ))
            .unwrap();
            assert_eq!(
                packed_expert_heap_bytes(&cfg, bits),
                2 * pm_gate.heap_bytes() + pm_down.heap_bytes(),
                "bits={bits}"
            );
        }
    }

    #[test]
    fn expert_read_time_reflects_both_bytes_and_rate() {
        let cfg = config::variant("dsvl2_tiny").unwrap();
        let p = ThroughputProfile::builtin();
        // 2-bit reads fewer bytes at a faster rate than 3-bit: strictly
        // quicker. 4-bit reads more bytes than 2-bit at a similar rate:
        // strictly slower.
        let t2 = p.expert_read_us(&cfg, 2).unwrap();
        let t3 = p.expert_read_us(&cfg, 3).unwrap();
        let t4 = p.expert_read_us(&cfg, 4).unwrap();
        assert!(t2 < t3, "{t2} {t3}");
        assert!(t2 < t4, "{t2} {t4}");
        assert!(p.expert_read_us(&cfg, 5).is_err());
    }

    #[test]
    fn bench_json_roundtrip_and_typed_errors() {
        let dir = std::env::temp_dir().join("mopeq_profile_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH_quant_throughput.json");
        std::fs::write(
            &path,
            r#"{"bench":"quant_throughput","qmatmul":{
                "2":{"gbs":1.5},"3":{"gbs":0.9},
                "4":{"gbs":1.8},"8":{"gbs":3.2}}}"#,
        )
        .unwrap();
        let p = ThroughputProfile::from_bench_json(&path).unwrap();
        assert_eq!(p.gbs_for(3), Some(0.9));
        assert_eq!(p.gbs.len(), 4);
        assert_eq!(p.source, path.display().to_string());

        std::fs::write(&path, r#"{"bench":"quant_throughput"}"#).unwrap();
        let err = ThroughputProfile::from_bench_json(&path).unwrap_err();
        assert!(matches!(
            err.downcast_ref::<SearchError>(),
            Some(SearchError::Profile { .. })
        ));

        std::fs::write(&path, "not json").unwrap();
        assert!(ThroughputProfile::from_bench_json(&path).is_err());
        std::fs::remove_file(&path).ok();
    }
}
