//! The search cost model: for every routed expert × palette width, the
//! three prices a candidate bit-width map pays —
//!
//! - **size**: wire bytes under the canonical `SizePolicy` accounting
//!   (`moe::expert_size_bits`), identical for every expert at a given
//!   width;
//! - **sensitivity-weighted error**: Hessian-trace importance
//!   (`importance::hessian` / any spec [`Metric`] the caller resolved)
//!   × the measured per-expert quantization MSE at that width, probed
//!   through the real quantizers (RTN data-free; GPTQ / AWQ / SignRound
//!   against a calibration capture) — the paper's §3.3 sensitivity
//!   argument turned into a per-(expert, width) number;
//! - **throughput**: predicted µs to stream the expert's packed weights
//!   through the profiled `qmatmul` kernel
//!   ([`ThroughputProfile::expert_read_us`]) — the MxMoE-style term
//!   that makes byte-inefficient widths (3-bit padding) pay their way.
//!
//! The scalarization ([`Objective`]) collapses error + throughput into
//! the single `cost[i][p]` table the solvers optimize; size is enforced
//! as the budget constraint, not scalarized.
//!
//! A measured [`TrafficPrior`] (`mopeq search --traffic`) multiplies
//! both the error and throughput terms per expert by its layer-mean-1
//! activation weight — a cold expert's quantization error barely
//! matters and its weights are rarely streamed, so the solver spends
//! the budget on the experts the workload actually routes to. With no
//! prior (or a uniform one, weight exactly 1.0) the table is
//! bit-identical to the traffic-less model.

use crate::adapt::TrafficPrior;
use crate::config::ModelConfig;
use crate::coordinator::quantize::probe_expert_mse;
use crate::engine::spec::QuantSpec;
use crate::importance::ImportanceMap;
use crate::moe::{expert_size_bits, PrecisionMap, WeightStore};
use crate::runtime::Session;
use crate::search::profile::{packed_expert_heap_bytes, ThroughputProfile};
use crate::search::solve::widths_to_indices;
use crate::search::{Objective, SearchError};
use anyhow::{bail, Result};

/// Everything the solvers need, precomputed: per-expert per-width
/// scalar costs plus the per-width byte/time tables for reporting.
#[derive(Clone, Debug)]
pub struct CostModel {
    /// candidate widths, strictly ascending
    pub palette: Vec<u8>,
    pub layers: usize,
    pub experts: usize,
    /// routed experts activated per token (throughput projection)
    pub top_k: usize,
    /// scalar solver objective, `[layer * experts + e][palette index]`
    pub cost: Vec<Vec<f64>>,
    /// the sensitivity-weighted error component alone (same indexing) —
    /// what the acceptance tests compare across allocators
    pub weighted_err: Vec<Vec<f64>>,
    /// wire (`SizePolicy`) bytes of one expert at each palette width
    pub wire_bytes: Vec<usize>,
    /// resident heap bytes of one expert at each palette width
    pub heap_bytes: Vec<usize>,
    /// predicted µs to stream one expert at each palette width
    pub read_us: Vec<f64>,
}

/// Predicted aggregates of one assignment under a [`CostModel`].
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CostSummary {
    pub mean_bits: f64,
    /// Σ importance × quantization MSE over all experts
    pub weighted_err: f64,
    /// Σ wire bytes (the `SizePolicy` expert term)
    pub wire_bytes: usize,
    /// Σ resident heap bytes (what a packed engine holds)
    pub heap_bytes: usize,
    /// predicted expert-weight read time per token: `top_k` activated
    /// experts per MoE layer, each at its layer-mean read cost
    pub read_us_per_token: f64,
}

impl CostModel {
    /// Probe the model and assemble the full cost table. `probe` names
    /// the quantizer whose reconstruction error prices each width (RTN
    /// is data-free; calibrated probes capture activations once at
    /// `seed`, exactly as a real build would).
    #[allow(clippy::too_many_arguments)]
    pub fn build(
        session: Option<&Session>,
        cfg: &ModelConfig,
        ws: &WeightStore,
        importance: &ImportanceMap,
        traffic: Option<&TrafficPrior>,
        palette: &[u8],
        probe: &QuantSpec,
        profile: &ThroughputProfile,
        objective: Objective,
        seed: u64,
    ) -> Result<CostModel> {
        let (layers, experts) = (cfg.moe_layers(), cfg.experts);
        if importance.layers() != layers || importance.experts() != experts
        {
            bail!(
                "importance map {}x{} != model {}x{}",
                importance.layers(),
                importance.experts(),
                layers,
                experts
            );
        }
        if let Some(t) = traffic {
            t.check_model(cfg)?;
        }
        profile.check_palette(palette)?;

        // one calibration capture feeds every width's probe (identical
        // to how a real engine build captures once and packs once)
        let kernel = crate::coordinator::MoeKernel::default();
        let calib = probe.capture(session, cfg, ws, kernel, seed)?;

        let n = layers * experts;
        let mut weighted_err = vec![Vec::with_capacity(palette.len()); n];
        let mut wire_bytes = Vec::with_capacity(palette.len());
        let mut heap_bytes = Vec::with_capacity(palette.len());
        let mut read_us = Vec::with_capacity(palette.len());
        for &bits in palette {
            let mse = probe_expert_mse(
                session,
                cfg,
                ws,
                bits,
                &probe.quantizer,
                calib.as_ref(),
            )?;
            for l in 0..layers {
                for e in 0..experts {
                    let w = traffic.map_or(1.0, |t| t.weight(l, e));
                    weighted_err[l * experts + e]
                        .push(importance.values[l][e] * mse[l][e] * w);
                }
            }
            // the canonical byte accounting shared with the offload
            // simulator and the size tables
            wire_bytes.push(crate::serve::expert_bytes(cfg, bits));
            heap_bytes.push(packed_expert_heap_bytes(cfg, bits));
            read_us.push(profile.expert_read_us(cfg, bits)?);
        }

        // scalarize error + throughput. The time term is normalized by
        // the slowest width and scaled by the mean per-expert error
        // span, so λ = 1 weighs "serve faster" and "quantize better"
        // in the same currency regardless of model scale.
        let lambda = match objective {
            Objective::Accuracy => 0.0,
            Objective::Balanced { lambda } => lambda,
        };
        let cost = if lambda == 0.0 {
            weighted_err.clone()
        } else {
            let last = palette.len() - 1;
            let err_span: f64 = weighted_err
                .iter()
                .map(|row| (row[0] - row[last]).max(0.0))
                .sum::<f64>()
                / n as f64;
            let t_max = read_us
                .iter()
                .cloned()
                .fold(f64::MIN, f64::max)
                .max(1e-12);
            // the time surcharge scales with the expert's traffic too:
            // a hot expert's packed weights are streamed on nearly
            // every token, a cold one's almost never
            weighted_err
                .iter()
                .enumerate()
                .map(|(i, row)| {
                    let w = traffic.map_or(1.0, |t| {
                        t.weight(i / experts, i % experts)
                    });
                    row.iter()
                        .zip(&read_us)
                        .map(|(&werr, &t)| {
                            werr + lambda * err_span * (t / t_max) * w
                        })
                        .collect()
                })
                .collect()
        };

        Ok(CostModel {
            palette: palette.to_vec(),
            layers,
            experts,
            top_k: cfg.top_k,
            cost,
            weighted_err,
            wire_bytes,
            heap_bytes,
            read_us,
        })
    }

    /// Experts in the flattened solver order.
    pub fn n_experts(&self) -> usize {
        self.layers * self.experts
    }

    /// An assignment (palette indices, flattened order) as a
    /// `PrecisionMap`.
    pub fn assignment_map(&self, assign: &[usize]) -> PrecisionMap {
        assert_eq!(assign.len(), self.n_experts());
        let bits = (0..self.layers)
            .map(|l| {
                (0..self.experts)
                    .map(|e| self.palette[assign[l * self.experts + e]])
                    .collect()
            })
            .collect();
        PrecisionMap { bits }
    }

    /// A `PrecisionMap` as palette indices in the solver order — typed
    /// [`SearchError::OffPaletteWidth`] for widths the model cannot
    /// price.
    pub fn map_indices(&self, map: &PrecisionMap) -> Result<Vec<usize>> {
        let assign = widths_to_indices(&map.bits, &self.palette)?;
        if assign.len() != self.n_experts() {
            bail!(
                "precision map has {} experts, cost model prices {}",
                assign.len(),
                self.n_experts()
            );
        }
        Ok(assign)
    }

    /// Predicted aggregates of an assignment — what the frontier
    /// records per point and the comparison table prints per row.
    pub fn summary(&self, assign: &[usize]) -> CostSummary {
        let n = self.n_experts();
        assert_eq!(assign.len(), n);
        let mut bits_sum = 0usize;
        let mut werr = 0.0f64;
        let mut wire = 0usize;
        let mut heap = 0usize;
        let mut us_sum = 0.0f64;
        for (i, &p) in assign.iter().enumerate() {
            bits_sum += self.palette[p] as usize;
            werr += self.weighted_err[i][p];
            wire += self.wire_bytes[p];
            heap += self.heap_bytes[p];
            us_sum += self.read_us[p];
        }
        CostSummary {
            mean_bits: bits_sum as f64 / n as f64,
            weighted_err: werr,
            wire_bytes: wire,
            heap_bytes: heap,
            // per token: top_k experts activate in each MoE layer at the
            // model-mean expert read cost
            read_us_per_token: self.top_k as f64
                * self.layers as f64
                * (us_sum / n as f64),
        }
    }

    /// Typed feasibility floor: the bit-sum cap below which no
    /// assignment exists.
    pub fn floor_bits(&self) -> usize {
        self.n_experts() * self.palette[0] as usize
    }
}

/// Convert a budget in average bits/expert into the solver's bit-sum
/// cap.
pub fn avg_bits_cap(n_experts: usize, max_mean_bits: f64) -> usize {
    (max_mean_bits * n_experts as f64).floor() as usize
}

/// The affine coefficients of `expert_size_bits` in the width:
/// `size(b) = A·b + B` for every quantizable width `b < 16` (the group
/// policy is fixed by the config). Single source for both directions
/// of the bytes ↔ bit-cap conversion.
fn size_affine(cfg: &ModelConfig) -> (usize, usize) {
    let a = expert_size_bits(cfg, 3) - expert_size_bits(cfg, 2);
    let b = expert_size_bits(cfg, 2) - 2 * a;
    (a, b)
}

/// Forward direction: the total expert wire bytes a bit-sum cap
/// implies — the budget bound `mopeq search --serve-check` asserts
/// measured resident bytes against. Inverse of [`bytes_cap`] by
/// construction (both read [`size_affine`]).
pub fn wire_bytes_at_cap(
    cfg: &ModelConfig,
    n_experts: usize,
    cap_bits: usize,
) -> usize {
    let (a, b) = size_affine(cfg);
    (a * cap_bits + n_experts * b).div_ceil(8)
}

/// Convert a total-wire-bytes budget into a bit-sum cap, using the fact
/// that `expert_size_bits` is affine in the width (`A·b + B` for b < 16
/// with the group policy fixed by the config): `Σ size(b_e) ≤ 8·bytes`
/// ⇔ `Σ b_e ≤ (8·bytes − n·B) / A`. Returns a typed error when even
/// the all-minimum-width model exceeds the byte budget.
pub fn bytes_cap(
    cfg: &ModelConfig,
    n_experts: usize,
    min_palette_bits: u8,
    budget_bytes: usize,
) -> Result<usize> {
    let (a, b) = size_affine(cfg);
    let total_bits = 8i128 * budget_bytes as i128;
    let cap = (total_bits - n_experts as i128 * b as i128) / a as i128;
    let floor = n_experts as i128 * min_palette_bits as i128;
    if cap < floor {
        let floor_bytes = (n_experts
            * expert_size_bits(cfg, min_palette_bits))
        .div_ceil(8);
        return Err(SearchError::InfeasibleBytes {
            budget_bytes,
            floor_bytes,
        }
        .into());
    }
    Ok(cap as usize)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config;
    use crate::importance::hessian_closed_form;
    use crate::moe::local_meta;

    fn tiny() -> (ModelConfig, WeightStore) {
        let cfg = config::variant("dsvl2_tiny").unwrap();
        let ws = WeightStore::init(&cfg, &local_meta(&cfg), 5);
        (cfg, ws)
    }

    fn rtn_model(objective: Objective) -> (ModelConfig, CostModel) {
        let (cfg, ws) = tiny();
        let imp = hessian_closed_form(&ws, &cfg).unwrap();
        let cm = CostModel::build(
            None,
            &cfg,
            &ws,
            &imp,
            None,
            &[2, 3, 4],
            &QuantSpec::rtn(),
            &ThroughputProfile::builtin(),
            objective,
            5,
        )
        .unwrap();
        (cfg, cm)
    }

    #[test]
    fn error_is_monotone_decreasing_in_width() {
        let (_, cm) = rtn_model(Objective::Accuracy);
        for row in &cm.weighted_err {
            assert!(row[0] > row[1] && row[1] > row[2], "{row:?}");
            assert!(row.iter().all(|v| v.is_finite() && *v >= 0.0));
        }
        // accuracy objective: the scalar cost IS the weighted error
        assert_eq!(cm.cost, cm.weighted_err);
    }

    #[test]
    fn balanced_objective_penalizes_slow_widths() {
        let (_, cm) = rtn_model(Objective::Balanced { lambda: 1.0 });
        // 3-bit is the slowest profiled width, so its scalar cost gets
        // the largest throughput surcharge over the raw error
        let surcharge: Vec<f64> = (0..3)
            .map(|p| cm.cost[0][p] - cm.weighted_err[0][p])
            .collect();
        assert!(surcharge[1] > surcharge[0], "{surcharge:?}");
        assert!(surcharge[1] > surcharge[2], "{surcharge:?}");
        // the surcharge is uniform across experts at a given width
        assert!(
            ((cm.cost[7][1] - cm.weighted_err[7][1]) - surcharge[1]).abs()
                < 1e-12
        );
    }

    #[test]
    fn summary_matches_uniform_accounting() {
        let (cfg, cm) = rtn_model(Objective::Accuracy);
        let n = cm.n_experts();
        let uni3 = vec![1usize; n]; // palette index 1 = 3-bit
        let s = cm.summary(&uni3);
        assert_eq!(s.mean_bits, 3.0);
        assert_eq!(
            s.wire_bytes,
            n * expert_size_bits(&cfg, 3).div_ceil(8)
        );
        assert_eq!(s.heap_bytes, n * packed_expert_heap_bytes(&cfg, 3));
        assert!(s.read_us_per_token > 0.0);
        assert!(s.weighted_err > 0.0);
    }

    #[test]
    fn map_roundtrips_through_indices() {
        let (cfg, cm) = rtn_model(Objective::Accuracy);
        let mut assign = vec![0usize; cm.n_experts()];
        for (i, a) in assign.iter_mut().enumerate() {
            *a = i % 3;
        }
        let map = cm.assignment_map(&assign);
        assert_eq!(map.bits.len(), cfg.moe_layers());
        assert_eq!(cm.map_indices(&map).unwrap(), assign);
        // off-palette widths are typed errors
        let mut bad = map.clone();
        bad.bits[0][0] = 8;
        let err = cm.map_indices(&bad).unwrap_err();
        assert_eq!(
            err.downcast_ref::<SearchError>(),
            Some(&SearchError::OffPaletteWidth { bits: 8 })
        );
    }

    #[test]
    fn uniform_traffic_prior_is_bit_identical_and_skew_reweights() {
        use crate::adapt::TrafficPrior;
        let (cfg, ws) = tiny();
        let imp = hessian_closed_form(&ws, &cfg).unwrap();
        let build = |traffic: Option<&TrafficPrior>| {
            CostModel::build(
                None,
                &cfg,
                &ws,
                &imp,
                traffic,
                &[2, 3, 4],
                &QuantSpec::rtn(),
                &ThroughputProfile::builtin(),
                Objective::Balanced { lambda: 1.0 },
                5,
            )
            .unwrap()
        };
        let plain = build(None);
        // a uniform prior (every weight exactly 1.0) reproduces the
        // traffic-less table bit-for-bit
        let uni = TrafficPrior::uniform(
            cfg.name.to_string(),
            cfg.moe_layers(),
            cfg.experts,
        );
        let with_uni = build(Some(&uni));
        assert_eq!(with_uni.cost, plain.cost);
        assert_eq!(with_uni.weighted_err, plain.weighted_err);
        // a skewed prior scales one expert's error AND surcharge
        let mut counts = vec![vec![1u64; cfg.experts]; cfg.moe_layers()];
        counts[0][0] = 1 + 2 * (cfg.experts as u64 - 1); // weight 2ish
        let skew = TrafficPrior::from_counts(cfg.name.to_string(), &counts);
        let w = skew.weight(0, 0);
        assert!(w > 1.0);
        let with_skew = build(Some(&skew));
        for p in 0..3 {
            assert!(
                (with_skew.weighted_err[0][p]
                    - w * plain.weighted_err[0][p])
                    .abs()
                    <= 1e-9 * plain.weighted_err[0][p].abs().max(1.0)
            );
        }
        // wrong variant / shape fail typed before probing anything
        let bad = TrafficPrior::uniform("other", cfg.moe_layers(), cfg.experts);
        let err = CostModel::build(
            None,
            &cfg,
            &ws,
            &imp,
            Some(&bad),
            &[2, 3, 4],
            &QuantSpec::rtn(),
            &ThroughputProfile::builtin(),
            Objective::Accuracy,
            5,
        )
        .unwrap_err();
        assert!(matches!(
            err.downcast_ref::<crate::adapt::AdaptError>(),
            Some(crate::adapt::AdaptError::TrafficVariant { .. })
        ));
    }

    #[test]
    fn bytes_cap_inverts_the_affine_size_formula() {
        let (cfg, _) = tiny();
        let n = cfg.total_experts();
        // budget = exactly a uniform-3-bit model in bytes → cap = 3n
        let bytes3 = n * expert_size_bits(&cfg, 3) / 8;
        let cap = bytes_cap(&cfg, n, 2, bytes3).unwrap();
        assert_eq!(cap, 3 * n);
        // the forward helper is the exact inverse (shared coefficients)
        assert_eq!(wire_bytes_at_cap(&cfg, n, cap), bytes3);
        // a cap that mixes widths still prices exactly like the
        // per-width table (affinity)
        assert_eq!(
            wire_bytes_at_cap(&cfg, 2, 6),
            2 * expert_size_bits(&cfg, 3).div_ceil(8)
        );
        // a budget below the all-2-bit floor is a typed error
        let floor = n * expert_size_bits(&cfg, 2) / 8;
        let err = bytes_cap(&cfg, n, 2, floor / 2).unwrap_err();
        assert!(matches!(
            err.downcast_ref::<SearchError>(),
            Some(SearchError::InfeasibleBytes { .. })
        ));
    }
}
