//! Pareto frontier sweep: solve the budgeted allocation at a ladder of
//! average-bit budgets, keep the non-dominated (size, error) points,
//! and persist the whole sweep as a self-describing artifact directory
//! —
//!
//! ```text
//! frontier-out/
//!   frontier.json     sweep metadata + per-point predictions + ranking
//!   point_00.json     SavedMap (map + provenance) of each kept point
//!   point_01.json     ...
//!   best.json         copy of the point selected for the requested
//!                     budget — what `mopeq serve --map` consumes
//! ```
//!
//! Every file round-trips byte-for-byte through [`crate::jsonx`]
//! (stable key order, shortest-roundtrip floats), and a corrupt or
//! partial directory loads back as a **typed** [`SearchError`] — never
//! a panic, never a silently truncated frontier.

use crate::engine::spec::{Provenance, SavedMap};
use crate::jsonx::Json;
use crate::search::cost::{avg_bits_cap, CostModel, CostSummary};
use crate::search::solve::{dp_solve, refine};
use crate::search::SearchError;
use anyhow::Result;
use std::path::Path;

/// One solved point of the sweep with its predicted aggregates.
#[derive(Clone, Debug, PartialEq)]
pub struct FrontierPoint {
    /// the average-bits budget this point was solved under
    pub budget_avg_bits: f64,
    pub mean_bits: f64,
    /// Σ expert wire bytes (`SizePolicy` accounting)
    pub wire_bytes: usize,
    /// Σ resident heap bytes a packed engine would hold
    pub heap_bytes: usize,
    /// predicted sensitivity-weighted quantization error
    pub weighted_err: f64,
    /// predicted expert-weight read µs per token
    pub read_us_per_token: f64,
    /// the `SavedMap` file of this point, relative to the frontier dir
    pub file: String,
}

/// Sweep metadata — the `frontier.json` document.
#[derive(Clone, Debug, PartialEq)]
pub struct Frontier {
    pub variant: String,
    /// objective label (`"accuracy"` / `"balanced(λ=…)"`)
    pub objective: String,
    pub palette: Vec<u8>,
    /// throughput-profile source (`"builtin"` or a bench JSON path)
    pub profile: String,
    /// index into `points` of the map selected for the requested budget
    pub best: usize,
    /// non-dominated points, ascending by mean bits
    pub points: Vec<FrontierPoint>,
}

impl Frontier {
    pub fn to_json(&self) -> Json {
        let points = self
            .points
            .iter()
            .map(|p| {
                Json::Obj(vec![
                    (
                        "budget_avg_bits".into(),
                        Json::Num(p.budget_avg_bits),
                    ),
                    ("mean_bits".into(), Json::Num(p.mean_bits)),
                    (
                        "wire_bytes".into(),
                        Json::Num(p.wire_bytes as f64),
                    ),
                    (
                        "heap_bytes".into(),
                        Json::Num(p.heap_bytes as f64),
                    ),
                    ("weighted_err".into(), Json::Num(p.weighted_err)),
                    (
                        "read_us_per_token".into(),
                        Json::Num(p.read_us_per_token),
                    ),
                    ("file".into(), Json::Str(p.file.clone())),
                ])
            })
            .collect();
        Json::Obj(vec![
            ("variant".into(), Json::Str(self.variant.clone())),
            ("objective".into(), Json::Str(self.objective.clone())),
            (
                "palette".into(),
                Json::Arr(
                    self.palette
                        .iter()
                        .map(|&b| Json::Num(b as f64))
                        .collect(),
                ),
            ),
            ("profile".into(), Json::Str(self.profile.clone())),
            ("best".into(), Json::Num(self.best as f64)),
            ("points".into(), Json::Arr(points)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<Frontier> {
        let mut points = Vec::new();
        for p in j.req("points")?.as_arr()? {
            points.push(FrontierPoint {
                budget_avg_bits: p.req("budget_avg_bits")?.as_f64()?,
                mean_bits: p.req("mean_bits")?.as_f64()?,
                wire_bytes: p.req("wire_bytes")?.as_usize()?,
                heap_bytes: p.req("heap_bytes")?.as_usize()?,
                weighted_err: p.req("weighted_err")?.as_f64()?,
                read_us_per_token: p
                    .req("read_us_per_token")?
                    .as_f64()?,
                file: p.req("file")?.as_str()?.to_string(),
            });
        }
        Ok(Frontier {
            variant: j.req("variant")?.as_str()?.to_string(),
            objective: j.req("objective")?.as_str()?.to_string(),
            palette: j
                .req("palette")?
                .as_arr()?
                .iter()
                .map(|v| {
                    let b = v.as_usize()?;
                    if b == 0 || b > u8::MAX as usize {
                        anyhow::bail!("palette width {b} out of range");
                    }
                    Ok(b as u8)
                })
                .collect::<Result<_>>()?,
            profile: j.req("profile")?.as_str()?.to_string(),
            best: j.req("best")?.as_usize()?,
            points,
        })
    }
}

/// A frontier with its point maps — what [`sweep`] produces and a
/// frontier directory (de)serializes.
#[derive(Clone, Debug, PartialEq)]
pub struct FrontierSet {
    pub meta: Frontier,
    /// aligned with `meta.points`
    pub maps: Vec<SavedMap>,
}

impl FrontierSet {
    /// The map selected for the requested budget.
    pub fn best_map(&self) -> &SavedMap {
        &self.maps[self.meta.best]
    }

    /// Write `frontier.json`, every point map, and `best.json` into
    /// `dir` (created if needed).
    pub fn save(&self, dir: &Path) -> Result<()> {
        std::fs::create_dir_all(dir)?;
        std::fs::write(
            dir.join("frontier.json"),
            self.meta.to_json().to_string(),
        )?;
        for (point, map) in self.meta.points.iter().zip(&self.maps) {
            map.save(&dir.join(&point.file))?;
        }
        self.best_map().save(&dir.join("best.json"))?;
        Ok(())
    }

    /// Load a frontier directory back. Corrupt or partial directories
    /// fail with typed [`SearchError`]s naming the offending file.
    pub fn load(dir: &Path) -> Result<FrontierSet> {
        let meta_path = dir.join("frontier.json");
        let bad = |detail: String| SearchError::FrontierMeta {
            path: meta_path.display().to_string(),
            detail,
        };
        let text = std::fs::read_to_string(&meta_path)
            .map_err(|e| bad(format!("read: {e}")))?;
        let json =
            Json::parse(&text).map_err(|e| bad(format!("parse: {e}")))?;
        let meta = Frontier::from_json(&json)
            .map_err(|e| bad(format!("schema: {e}")))?;
        if meta.points.is_empty() {
            return Err(bad("frontier has no points".into()).into());
        }
        if meta.best >= meta.points.len() {
            return Err(bad(format!(
                "best index {} out of range ({} points)",
                meta.best,
                meta.points.len()
            ))
            .into());
        }
        let mut maps = Vec::with_capacity(meta.points.len());
        for point in &meta.points {
            let path = dir.join(&point.file);
            if !path.exists() {
                return Err(SearchError::MissingPoint {
                    file: path.display().to_string(),
                }
                .into());
            }
            let map = SavedMap::load(&path).map_err(|e| {
                SearchError::FrontierMeta {
                    path: path.display().to_string(),
                    detail: format!("point map: {e}"),
                }
            })?;
            if map.variant != meta.variant {
                return Err(SearchError::PointVariant {
                    expected: meta.variant.clone(),
                    found: map.variant,
                }
                .into());
            }
            maps.push(map);
        }
        Ok(FrontierSet { meta, maps })
    }
}

/// Solve the budget ladder and keep the Pareto-optimal points.
///
/// `budgets` are average-bits caps (ascending recommended, any order
/// accepted); `request` selects the `best` point — the lowest
/// predicted-error point whose mean bits fit under it. Dominated points
/// (another point with ≤ wire bytes **and** ≤ weighted error, one
/// strictly) are dropped; duplicate solutions collapse to one point.
#[allow(clippy::too_many_arguments)]
pub fn sweep(
    cm: &CostModel,
    variant: &str,
    metric_label: &str,
    objective_label: &str,
    budgets: &[f64],
    request: f64,
    do_refine: bool,
    profile_source: &str,
) -> Result<FrontierSet> {
    if budgets.is_empty() {
        return Err(SearchError::EmptyFrontier.into());
    }
    let n = cm.n_experts();
    let mut solved: Vec<(f64, CostSummary, Vec<usize>)> = Vec::new();
    for &budget in budgets {
        let cap = avg_bits_cap(n, budget);
        let mut assign = dp_solve(&cm.cost, &cm.palette, cap)?;
        if do_refine {
            refine(&mut assign, &cm.cost, &cm.palette, cap);
        }
        let summary = cm.summary(&assign);
        if solved.iter().any(|(_, _, a)| *a == assign) {
            continue; // the ladder resolved to an already-kept map
        }
        solved.push((budget, summary, assign));
    }
    // Pareto filter on (wire bytes, weighted error)
    let dominated = |a: &CostSummary, by: &CostSummary| {
        by.wire_bytes <= a.wire_bytes
            && by.weighted_err <= a.weighted_err
            && (by.wire_bytes < a.wire_bytes
                || by.weighted_err < a.weighted_err)
    };
    let mut kept: Vec<(f64, CostSummary, Vec<usize>)> = Vec::new();
    for (budget, summary, assign) in solved.iter() {
        if !solved.iter().any(|(_, other, _)| dominated(summary, other)) {
            kept.push((*budget, *summary, assign.clone()));
        }
    }
    if kept.is_empty() {
        return Err(SearchError::EmptyFrontier.into());
    }
    kept.sort_by(|a, b| {
        a.1.mean_bits
            .partial_cmp(&b.1.mean_bits)
            .unwrap_or(std::cmp::Ordering::Equal)
    });
    // best = lowest predicted error among points fitting the request —
    // no silent fallback: a ladder whose every point exceeds the
    // request must fail typed, never hand out an over-budget best.json
    let mut best: Option<usize> = None;
    let mut best_err = f64::INFINITY;
    for (i, (_, summary, _)) in kept.iter().enumerate() {
        if summary.mean_bits <= request + 1e-9
            && summary.weighted_err < best_err
        {
            best_err = summary.weighted_err;
            best = Some(i);
        }
    }
    let Some(best) = best else {
        return Err(SearchError::NoPointUnderBudget {
            request_avg_bits: request,
        }
        .into());
    };
    let solver = if do_refine { "search(dp+refine)" } else { "search(dp)" };
    let mut points = Vec::with_capacity(kept.len());
    let mut maps = Vec::with_capacity(kept.len());
    for (i, (budget, summary, assign)) in kept.iter().enumerate() {
        let map = cm.assignment_map(assign);
        let provenance = Provenance {
            metric: metric_label.to_string(),
            granularity: solver.to_string(),
            palette: cm.palette.clone(),
            budget: Some(*budget),
            mean_bits: map.mean_bits(),
            layer_mean_bits: map.layer_mean_bits(),
        };
        points.push(FrontierPoint {
            budget_avg_bits: *budget,
            mean_bits: summary.mean_bits,
            wire_bytes: summary.wire_bytes,
            heap_bytes: summary.heap_bytes,
            weighted_err: summary.weighted_err,
            read_us_per_token: summary.read_us_per_token,
            file: format!("point_{i:02}.json"),
        });
        maps.push(SavedMap {
            variant: variant.to_string(),
            map,
            provenance: Some(provenance),
        });
    }
    Ok(FrontierSet {
        meta: Frontier {
            variant: variant.to_string(),
            objective: objective_label.to_string(),
            palette: cm.palette.clone(),
            profile: profile_source.to_string(),
            best,
            points,
        },
        maps,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config;
    use crate::engine::spec::QuantSpec;
    use crate::importance::hessian_closed_form;
    use crate::moe::{local_meta, WeightStore};
    use crate::search::profile::ThroughputProfile;
    use crate::search::Objective;

    fn model() -> CostModel {
        let cfg = config::variant("dsvl2_tiny").unwrap();
        let ws = WeightStore::init(&cfg, &local_meta(&cfg), 9);
        let imp = hessian_closed_form(&ws, &cfg).unwrap();
        CostModel::build(
            None,
            &cfg,
            &ws,
            &imp,
            None,
            &[2, 3, 4],
            &QuantSpec::rtn(),
            &ThroughputProfile::builtin(),
            Objective::Accuracy,
            9,
        )
        .unwrap()
    }

    #[test]
    fn sweep_is_pareto_and_monotone() {
        let cm = model();
        let set = sweep(
            &cm,
            "dsvl2_tiny",
            "hessian(closed-form)",
            "accuracy",
            &[2.0, 2.5, 3.0, 3.5, 4.0],
            3.0,
            true,
            "builtin",
        )
        .unwrap();
        let pts = &set.meta.points;
        assert!(pts.len() >= 2, "{pts:?}");
        // ascending in size, strictly descending in predicted error
        for w in pts.windows(2) {
            assert!(w[0].wire_bytes < w[1].wire_bytes);
            assert!(w[0].weighted_err > w[1].weighted_err);
        }
        // the selected point fits the requested budget
        let best = &pts[set.meta.best];
        assert!(best.mean_bits <= 3.0 + 1e-9);
        assert_eq!(set.best_map().map.bits.len(), cm.layers);
        // every map matches its recorded mean
        for (p, m) in pts.iter().zip(&set.maps) {
            assert!((m.map.mean_bits() - p.mean_bits).abs() < 1e-9);
            assert_eq!(m.provenance.as_ref().unwrap().budget,
                       Some(p.budget_avg_bits));
        }
    }

    #[test]
    fn frontier_json_roundtrips_byte_for_byte() {
        let cm = model();
        let set = sweep(
            &cm,
            "dsvl2_tiny",
            "hessian(closed-form)",
            "accuracy",
            &[2.0, 3.0, 4.0],
            3.0,
            false,
            "builtin",
        )
        .unwrap();
        let text = set.meta.to_json().to_string();
        let back =
            Frontier::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, set.meta);
        // re-serialization is byte-identical (stable key order + floats)
        assert_eq!(back.to_json().to_string(), text);
        // out-of-range palette widths fail instead of truncating
        let corrupt = text.replace("[2,3,4]", "[260,3,4]");
        let err =
            Frontier::from_json(&Json::parse(&corrupt).unwrap())
                .unwrap_err();
        assert!(err.to_string().contains("out of range"), "{err}");
    }

    #[test]
    fn ladder_entirely_over_the_request_is_a_typed_error() {
        // no silent over-budget best.json: a ladder whose every point
        // exceeds the requested budget must fail typed
        let cm = model();
        let err = sweep(
            &cm,
            "dsvl2_tiny",
            "hessian(closed-form)",
            "accuracy",
            &[3.5, 4.0],
            3.0,
            false,
            "builtin",
        )
        .unwrap_err();
        assert_eq!(
            err.downcast_ref::<SearchError>(),
            Some(&SearchError::NoPointUnderBudget {
                request_avg_bits: 3.0
            })
        );
    }

    #[test]
    fn empty_budget_ladder_is_a_typed_error() {
        let cm = model();
        let err = sweep(
            &cm,
            "dsvl2_tiny",
            "m",
            "accuracy",
            &[],
            3.0,
            false,
            "builtin",
        )
        .unwrap_err();
        assert_eq!(
            err.downcast_ref::<SearchError>(),
            Some(&SearchError::EmptyFrontier)
        );
    }
}
