//! Pareto allocation search — accuracy/throughput co-designed bit-width
//! maps over the PR 4 spec grammar.
//!
//! MoPEQ's Algorithm 2 clusters experts by sensitivity and
//! `AvgBitsBudget` demotes greedily; this subsystem instead treats the
//! per-expert width choice as an explicit **global optimization** (the
//! GEMQ framing), scored by a [`CostModel`] that prices every
//! (expert, width) pair on three axes — `SizePolicy` bytes,
//! sensitivity-weighted quantization error, and measured packed-kernel
//! throughput (the MxMoE observation that accuracy-only allocation
//! leaves throughput on the table) — and solved **exactly** by a
//! multiple-choice-knapsack DP plus a marginal-cost local refiner that
//! strictly dominates the greedy demotion pass on its own objective.
//!
//! Entry points:
//! - [`run_search`] — one budget, one map (what
//!   `PrecisionSource::Searched` / `EngineBuilder::auto` resolve
//!   through);
//! - [`frontier::sweep`] — a budget ladder → ranked Pareto
//!   [`frontier::FrontierSet`] artifact directory (what
//!   `mopeq search --frontier-out` writes and `mopeq serve --map`
//!   consumes);
//! - [`CostModel`] / [`solve`] — the pieces, for tests and benches.

pub mod cost;
pub mod frontier;
pub mod profile;
pub mod solve;

pub use cost::{CostModel, CostSummary};
pub use frontier::{Frontier, FrontierPoint, FrontierSet};
pub use profile::ThroughputProfile;

use crate::config::ModelConfig;
use crate::engine::spec::{
    AllocPolicy, AvgBitsBudget, Metric, Provenance, QuantSpec, Resolver,
};
use crate::importance::ImportanceMap;
use crate::moe::{PrecisionMap, WeightStore};
use crate::quant::pack;
use crate::runtime::Session;
use anyhow::Result;

/// What the search optimizes beyond the size budget.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Objective {
    /// minimize sensitivity-weighted quantization error alone
    Accuracy,
    /// error plus `λ ×` (normalized packed-kernel read time) — `λ = 1`
    /// weighs a width's full throughput penalty like the mean
    /// per-expert error span, so byte-inefficient widths (3-bit
    /// padding) must buy their keep in accuracy
    Balanced { lambda: f64 },
}

impl Objective {
    pub fn label(&self) -> String {
        match self {
            Objective::Accuracy => "accuracy".into(),
            Objective::Balanced { lambda } => {
                format!("balanced(lambda={lambda})")
            }
        }
    }
}

/// The size constraint the solver enforces.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum SearchBudget {
    /// mean assigned bits/expert ≤ this
    AvgBits(f64),
    /// Σ expert wire bytes (`SizePolicy` accounting) ≤ this
    TotalBytes(usize),
}

/// A complete search request — the declarative type behind
/// `PrecisionSource::Searched` and `mopeq search`.
#[derive(Clone, Debug)]
pub struct SearchSpec {
    /// importance metric (any spec-grammar [`Metric`]; the default is
    /// the paper's data-free closed-form Hessian)
    pub metric: Metric,
    /// candidate widths, strictly ascending, every one packable and
    /// profiled
    pub palette: Vec<u8>,
    pub budget: SearchBudget,
    pub objective: Objective,
    /// which quantizer's reconstruction error prices each width (RTN is
    /// data-free; GPTQ / AWQ / SignRound probe against a calibration
    /// capture and therefore need a session)
    pub probe: QuantSpec,
    /// run the local-search refiner after the DP (kept on by default;
    /// off reproduces the raw DP optimum for ablations)
    pub refine: bool,
    /// packed-kernel throughput profile (built-in table or a measured
    /// `BENCH_quant_throughput.json`)
    pub profile: ThroughputProfile,
    /// measured activation-frequency prior (`mopeq search --traffic`);
    /// `None` prices every expert as equally hot — identical tables to
    /// a uniform prior, bit-for-bit
    pub traffic: Option<crate::adapt::TrafficPrior>,
}

impl SearchSpec {
    /// "Best map under `max_mean_bits` average bits": paper-default
    /// metric and palette, RTN probe, accuracy objective, refiner on.
    pub fn avg_bits(max_mean_bits: f64) -> SearchSpec {
        SearchSpec {
            metric: AllocPolicy::default().metric,
            palette: AllocPolicy::default().palette,
            budget: SearchBudget::AvgBits(max_mean_bits),
            objective: Objective::Accuracy,
            probe: QuantSpec::rtn(),
            refine: true,
            profile: ThroughputProfile::builtin(),
            traffic: None,
        }
    }

    /// Typed validation of everything knowable without the model —
    /// shares the spec grammar's palette/metric/budget checks
    /// (`SpecError`) and adds the search-specific ones
    /// ([`SearchError`]).
    pub fn validate(&self) -> Result<()> {
        // metric / palette shape / avg-bits floor: the same typed
        // SpecErrors AllocPolicy raises, so CLI and builder users see
        // one error vocabulary
        let budget = match self.budget {
            SearchBudget::AvgBits(b) => {
                Some(AvgBitsBudget { max_mean_bits: b })
            }
            SearchBudget::TotalBytes(_) => None, // floor needs the config
        };
        AllocPolicy {
            metric: self.metric.clone(),
            granularity: crate::cluster::Granularity::ModelWise,
            palette: self.palette.clone(),
            budget,
        }
        .validate()?;
        self.probe.validate()?;
        for &bits in &self.palette {
            if !pack::packable(bits) {
                return Err(SearchError::UnpackableWidth { bits }.into());
            }
        }
        self.profile.check_palette(&self.palette)?;
        Ok(())
    }

    /// Whether resolving this spec must execute the model (importance
    /// profiling or a calibrated error probe).
    pub fn needs_model_runs(&self) -> bool {
        self.metric.needs_model_runs() || self.probe.quantizer.needs_calib()
    }

    /// The bit-sum cap this budget implies for `cfg`.
    pub fn cap_bits(&self, cfg: &ModelConfig) -> Result<usize> {
        let n = cfg.total_experts();
        match self.budget {
            SearchBudget::AvgBits(b) => Ok(cost::avg_bits_cap(n, b)),
            SearchBudget::TotalBytes(bytes) => {
                cost::bytes_cap(cfg, n, self.palette[0], bytes)
            }
        }
    }

    /// The budget as average bits/expert (byte budgets converted via
    /// the cap) — what frontier ranking and provenance record.
    pub fn budget_avg_bits(&self, cfg: &ModelConfig) -> Result<f64> {
        match self.budget {
            SearchBudget::AvgBits(b) => Ok(b),
            SearchBudget::TotalBytes(_) => Ok(self.cap_bits(cfg)? as f64
                / cfg.total_experts() as f64),
        }
    }
}

/// A solved search: the map, its self-describing provenance, and the
/// predicted aggregates.
#[derive(Clone, Debug)]
pub struct SearchOutcome {
    pub map: PrecisionMap,
    pub provenance: Provenance,
    pub summary: CostSummary,
}

/// Resolve a [`SearchSpec`] end to end over one model's reference
/// weights: importance → cost model → exact DP (→ refiner) → map. The
/// single-budget path `PrecisionSource::Searched` and
/// `EngineBuilder::auto` build through; `mopeq search` drives the same
/// stages plus the frontier sweep.
pub fn run_search(
    session: Option<&Session>,
    cfg: &ModelConfig,
    ws: &WeightStore,
    spec: &SearchSpec,
    seed: u64,
) -> Result<SearchOutcome> {
    spec.validate()?;
    let importance = resolve_importance(session, cfg, ws, &spec.metric, seed)?;
    let cm = CostModel::build(
        session,
        cfg,
        ws,
        &importance,
        spec.traffic.as_ref(),
        &spec.palette,
        &spec.probe,
        &spec.profile,
        spec.objective,
        seed,
    )?;
    let cap = spec.cap_bits(cfg)?;
    let mut assign = solve::dp_solve(&cm.cost, &cm.palette, cap)?;
    if spec.refine {
        solve::refine(&mut assign, &cm.cost, &cm.palette, cap);
    }
    let summary = cm.summary(&assign);
    let map = cm.assignment_map(&assign);
    let provenance = Provenance {
        // record that the map was priced under a measured prior — a
        // traffic-weighted map is not interchangeable with a uniform one
        metric: match &spec.traffic {
            Some(_) => format!("{}+traffic", spec.metric.label()),
            None => spec.metric.label(),
        },
        granularity: if spec.refine {
            "search(dp+refine)".into()
        } else {
            "search(dp)".into()
        },
        palette: spec.palette.clone(),
        budget: Some(spec.budget_avg_bits(cfg)?),
        mean_bits: map.mean_bits(),
        layer_mean_bits: map.layer_mean_bits(),
    };
    Ok(SearchOutcome { map, provenance, summary })
}

/// Resolve a spec-grammar metric into its importance map through the
/// shared [`Resolver`] (identical values to what `AllocPolicy` builds
/// see, by construction).
pub fn resolve_importance(
    session: Option<&Session>,
    cfg: &ModelConfig,
    ws: &WeightStore,
    metric: &Metric,
    seed: u64,
) -> Result<ImportanceMap> {
    match session {
        Some(s) => Resolver::new(s, cfg, ws, seed).importance(metric),
        None => Resolver::sessionless(cfg, ws, seed).importance(metric),
    }
}

/// Typed errors of the search subsystem. (Spec-shape problems — empty
/// or unsorted palettes, degenerate metrics, avg-bits budgets below the
/// palette floor — reuse the grammar's `SpecError` vocabulary; these
/// cover what only the search layer can know.)
#[derive(Clone, Debug, PartialEq)]
pub enum SearchError {
    /// a palette width with no packed u32 execution layout
    UnpackableWidth { bits: u8 },
    /// a palette width the throughput profile cannot price
    NoProfileEntry { bits: u8 },
    /// a bench-profile artifact that is unreadable or malformed
    Profile { path: String, detail: String },
    /// the bit-sum cap is below the all-minimum-width floor
    InfeasibleBits { cap_bits: usize, floor_bits: usize },
    /// a byte budget below the all-minimum-width model size
    InfeasibleBytes { budget_bytes: usize, floor_bytes: usize },
    /// an assignment width the cost table cannot price
    OffPaletteWidth { bits: u8 },
    /// a sweep with no budgets (or no surviving points)
    EmptyFrontier,
    /// every swept point exceeds the requested budget — there is no
    /// `best.json` to select
    NoPointUnderBudget { request_avg_bits: f64 },
    /// a frontier directory whose metadata is missing/corrupt
    FrontierMeta { path: String, detail: String },
    /// frontier metadata names a point file that does not exist
    MissingPoint { file: String },
    /// a point map inside the frontier names a different variant
    PointVariant { expected: String, found: String },
}

impl std::fmt::Display for SearchError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SearchError::UnpackableWidth { bits } => write!(
                f,
                "palette width {bits} has no packed u32 layout (packable \
                 widths: 2, 3, 4, 8)"
            ),
            SearchError::NoProfileEntry { bits } => write!(
                f,
                "throughput profile has no entry for width {bits} — \
                 re-run the quant_throughput bench or drop the width"
            ),
            SearchError::Profile { path, detail } => {
                write!(f, "throughput profile {path}: {detail}")
            }
            SearchError::InfeasibleBits { cap_bits, floor_bits } => write!(
                f,
                "bit budget {cap_bits} is below the all-minimum-width \
                 floor {floor_bits}"
            ),
            SearchError::InfeasibleBytes { budget_bytes, floor_bytes } => {
                write!(
                    f,
                    "byte budget {budget_bytes} is below the \
                     all-minimum-width model size {floor_bytes}"
                )
            }
            SearchError::OffPaletteWidth { bits } => write!(
                f,
                "width {bits} is not in the search palette — the cost \
                 model cannot price it"
            ),
            SearchError::EmptyFrontier => {
                write!(f, "frontier sweep has no budget points")
            }
            SearchError::NoPointUnderBudget { request_avg_bits } => {
                write!(
                    f,
                    "no swept point fits the requested budget of \
                     {request_avg_bits} avg bits — include the request \
                     in the budget ladder"
                )
            }
            SearchError::FrontierMeta { path, detail } => {
                write!(f, "frontier artifact {path}: {detail}")
            }
            SearchError::MissingPoint { file } => write!(
                f,
                "frontier names point file {file}, which does not exist"
            ),
            SearchError::PointVariant { expected, found } => write!(
                f,
                "frontier point map is for `{found}`, frontier is for \
                 `{expected}`"
            ),
        }
    }
}

impl std::error::Error for SearchError {}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config;
    use crate::engine::spec::SpecError;
    use crate::moe::local_meta;

    #[test]
    fn default_spec_is_the_paper_setting_plus_a_budget() {
        let spec = SearchSpec::avg_bits(3.0);
        assert_eq!(spec.metric, AllocPolicy::default().metric);
        assert_eq!(spec.palette, vec![2, 3, 4]);
        assert_eq!(spec.budget, SearchBudget::AvgBits(3.0));
        assert!(spec.refine);
        assert!(!spec.needs_model_runs());
        spec.validate().unwrap();
    }

    #[test]
    fn validation_reuses_the_spec_grammar_errors() {
        let mut spec = SearchSpec::avg_bits(3.0);
        spec.palette = vec![4, 2];
        assert!(matches!(
            spec.validate().unwrap_err().downcast_ref::<SpecError>(),
            Some(SpecError::UnsortedPalette { .. })
        ));
        spec.palette = vec![];
        assert!(matches!(
            spec.validate().unwrap_err().downcast_ref::<SpecError>(),
            Some(SpecError::EmptyPalette)
        ));
        let spec = SearchSpec::avg_bits(1.0);
        assert!(matches!(
            spec.validate().unwrap_err().downcast_ref::<SpecError>(),
            Some(SpecError::InfeasibleBudget { .. })
        ));
    }

    #[test]
    fn search_specific_validation_is_typed() {
        // width 5 quantizes fine but has no packed layout: the search
        // must reject it rather than plan a map the engine serves dense
        let mut spec = SearchSpec::avg_bits(5.5);
        spec.palette = vec![2, 4, 5];
        assert_eq!(
            spec.validate()
                .unwrap_err()
                .downcast_ref::<SearchError>(),
            Some(&SearchError::UnpackableWidth { bits: 5 })
        );
        // packable but unprofiled width
        let mut spec = SearchSpec::avg_bits(3.0);
        spec.profile.gbs.retain(|&(b, _)| b != 3);
        assert_eq!(
            spec.validate()
                .unwrap_err()
                .downcast_ref::<SearchError>(),
            Some(&SearchError::NoProfileEntry { bits: 3 })
        );
    }

    #[test]
    fn run_search_lands_under_the_budget_sessionless() {
        let cfg = config::variant("dsvl2_tiny").unwrap();
        let ws = WeightStore::init(&cfg, &local_meta(&cfg), 4);
        let out =
            run_search(None, &cfg, &ws, &SearchSpec::avg_bits(3.0), 4)
                .unwrap();
        assert!(out.map.mean_bits() <= 3.0);
        assert_eq!(out.provenance.budget, Some(3.0));
        assert!(out.provenance.granularity.contains("dp+refine"));
        assert!(out.summary.weighted_err > 0.0);
        // the budget binds: an unconstrained model would be all 4-bit
        assert!(out.map.mean_bits() > 2.0);
    }

    #[test]
    fn byte_budget_resolves_to_the_same_grammar() {
        let cfg = config::variant("dsvl2_tiny").unwrap();
        let ws = WeightStore::init(&cfg, &local_meta(&cfg), 4);
        // a byte budget equal to the uniform-3-bit model
        let bytes = cfg.total_experts()
            * crate::moe::expert_size_bits(&cfg, 3)
            / 8;
        let mut spec = SearchSpec::avg_bits(3.0);
        spec.budget = SearchBudget::TotalBytes(bytes);
        let out = run_search(None, &cfg, &ws, &spec, 4).unwrap();
        assert!(out.summary.wire_bytes <= bytes);
        // and an impossible byte budget is typed
        spec.budget = SearchBudget::TotalBytes(16);
        let err = run_search(None, &cfg, &ws, &spec, 4).unwrap_err();
        assert!(matches!(
            err.downcast_ref::<SearchError>(),
            Some(SearchError::InfeasibleBytes { .. })
        ));
    }
}
