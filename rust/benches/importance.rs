//! Importance-metric cost: Hutchinson estimation (host closed-form HVP
//! vs the AOT'd autodiff HLO), full-model closed form, and the
//! activation-frequency profiler — the "data-free vs calibration"
//! trade-off of paper §3.

use mopeq::benchx::{bench, bench_items, section};
use mopeq::config;
use mopeq::coordinator::ModelExecutor;
use mopeq::importance::{
    hessian::hutchinson_host, hessian_closed_form, profile_frequency,
};
use mopeq::moe::{local_meta, WeightStore};
use mopeq::rng::Rng;
use mopeq::runtime::{Session, Value};
use mopeq::tensor::Tensor;

fn main() {
    let cfg = config::variant("dsvl2_tiny").unwrap();
    let ws = WeightStore::init(&cfg, &local_meta(&cfg), 0);
    let mut rng = Rng::new(1);

    section("hessian trace, one expert FC (n=2048)");
    let w = Tensor::randn(&mut rng, &[2048], 1.0);
    for m in [8usize, 32] {
        bench_items(&format!("hutchinson_host_m{m}"), m as f64, || {
            hutchinson_host(&w, m, &mut rng)
        });
    }

    section("hessian trace, whole model");
    bench("closed_form_dsvl2_tiny (768 experts)", || {
        hessian_closed_form(&ws, &cfg).unwrap()
    });

    match Session::open_default() {
        Ok(s) => {
            section("HLO autodiff HVP (per probe)");
            let v = Tensor::new(&[2048], rng.rademacher_vec(2048));
            let _ = s.exec(
                "shared/hvp_frob_n2048",
                &[Value::F32(w.clone()), Value::F32(v.clone())],
            );
            bench("hvp_frob_hlo_call", || {
                s.exec(
                    "shared/hvp_frob_n2048",
                    &[Value::F32(w.clone()), Value::F32(v.clone())],
                )
                .unwrap()
            });

            section("activation-frequency profiler (4 calib batches)");
            let exec = ModelExecutor::new(&s, &cfg, &ws).unwrap();
            let _ = exec.warm();
            bench("profile_frequency_4batches", || {
                profile_frequency(&exec, &cfg, 4, 0).unwrap()
            });
        }
        Err(e) => println!("(skipping HLO benches: {e})"),
    }
}
