//! Table regeneration bench — the paper's evaluation grid. Runs the nine
//! method rows (Tables 2–5) for each sim model, prints the tables, and
//! writes CSVs to reports/.
//!
//! Runtime scales with (models × rows × tasks × samples); the default is
//! the tiny model with reduced sampling so `cargo bench` stays tractable
//! on one core. Set:
//!   MOPEQ_FULL=1        all four models, full sampling (tables 2–5)
//!   MOPEQ_MODELS=a,b    explicit model list
//!   MOPEQ_SAMPLES=n     eval samples per task

use mopeq::config;
use mopeq::coordinator::{MethodSpec, Pipeline};
use mopeq::report;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let full = std::env::var_os("MOPEQ_FULL").is_some();
    let models: Vec<String> = match std::env::var("MOPEQ_MODELS") {
        Ok(s) => s.split(',').map(|x| x.trim().to_string()).collect(),
        Err(_) if full => config::variants()
            .iter()
            .map(|c| c.name.to_string())
            .collect(),
        Err(_) => vec!["dsvl2_tiny".into(), "molmoe".into()],
    };
    let samples: usize = std::env::var("MOPEQ_SAMPLES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(if full { 64 } else { 24 });

    println!("{}", report::table1(&config::variants()));
    report::write_report("table1.txt",
                         &report::table1(&config::variants()))?;

    for model in &models {
        let t0 = Instant::now();
        let mut p = Pipeline::open(model, 0)?;
        p.eval_samples = samples;
        p.hessian_closed_form = !full; // exact trace keeps quick mode quick
        if !full {
            p.calib_batches = 8;
            p.signround.steps = 20;
        }
        let mut results = Vec::new();
        for spec in MethodSpec::table_rows() {
            let r0 = Instant::now();
            let r = p.run_method(&spec)?;
            eprintln!(
                "  [{model}] {:<38} {:>6.1}s  size {:.2} MB  mean acc {:.3}",
                r.label,
                r0.elapsed().as_secs_f64(),
                r.size_mb,
                r.scores.mean()
            );
            results.push(r);
        }
        let table = report::method_table(&p.cfg, &results);
        println!("{table}");
        report::write_report(&format!("table_{model}.txt"), &table)?;
        report::write_report(
            &format!("table_{model}.csv"),
            &report::method_table_csv(&p.cfg, &results),
        )?;
        println!(
            "[{model}] done in {:.1}s (n={samples}/task)\n",
            t0.elapsed().as_secs_f64()
        );
    }
    println!("CSVs in {}", report::reports_dir().display());
    Ok(())
}
