//! Quantizer throughput: RTN / GPTQ / AWQ host paths, bit pack/unpack,
//! and the SignRound HLO step — the cost side of the paper's method
//! (PTQ cost per expert FC layer).

use mopeq::benchx::{bench, bench_items, section};
use mopeq::coordinator::{signround_optimize, SignRoundConfig};
use mopeq::quant::{self, awq, gptq, pack};
use mopeq::rng::Rng;
use mopeq::runtime::Session;
use mopeq::tensor::Tensor;

fn main() {
    let mut rng = Rng::new(0);
    let w = Tensor::randn(&mut rng, &[64, 32], 0.5);
    let x = Tensor::randn(&mut rng, &[256, 64], 1.0);

    section("host quantizers (one expert FC 64x32)");
    for bits in [2u8, 3, 4] {
        bench(&format!("rtn_b{bits}"), || {
            quant::rtn_quantize(&w, bits, 32)
        });
    }
    bench("gptq_b4 (256 calib rows)", || {
        gptq::gptq_quantize(&w, &x, 4, 32, 0.01).unwrap()
    });
    bench("awq_b4 (256 calib rows)", || {
        awq::awq_quantize(&w, &x, 4, 32, 0.5)
    });

    section("bit packing (64x32 codes)");
    let qm = quant::rtn_quantize(&w, 4, 32);
    for bits in [2u8, 3, 4, 8] {
        let q = quant::rtn_quantize(&w, bits, 32);
        bench_items(&format!("pack_b{bits}"), (64 * 32) as f64, || {
            pack::pack(&q.codes, 64, 32, bits).unwrap()
        });
    }
    let packed = pack::pack(&qm.codes, 64, 32, 4).unwrap();
    bench_items("unpack_b4", (64 * 32) as f64, || {
        pack::unpack(&packed, 64, 32, 4)
    });
    bench("dequantize_b4", || qm.dequantize());

    section("SignRound HLO step (Pallas qdq fwd + STE bwd + SignSGD)");
    match Session::open_default() {
        Ok(s) => {
            let xs = Tensor::randn(&mut rng, &[64, 64], 1.0);
            let cfg = SignRoundConfig { steps: 10, lr: 0.02, calib_rows: 64 };
            // warm the executable so the bench measures steps, not compile
            let _ = signround_optimize(&s, &w, &xs, 2, 32, &cfg);
            for bits in [2u8, 4] {
                bench_items(
                    &format!("signround_10steps_b{bits}"),
                    10.0,
                    || signround_optimize(&s, &w, &xs, bits, 32, &cfg)
                        .unwrap(),
                );
            }
        }
        Err(e) => println!("(skipping HLO benches: {e})"),
    }
}
