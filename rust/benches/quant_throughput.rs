//! Quantizer throughput: RTN / GPTQ / AWQ host paths, bit pack/unpack,
//! the fused packed qmatmul kernels vs the f32 dense baseline (weight
//! GB/s — the §5.4 bandwidth argument, measured), and the SignRound HLO
//! step — the cost side of the paper's method (PTQ cost per expert FC
//! layer).
//!
//! Emits `reports/BENCH_quant_throughput.json` — the measured kernel
//! profile `mopeq search --profile` feeds into the search `CostModel`
//! (`ThroughputProfile::from_bench_json`), and the perf-trajectory
//! artifact diffed across PRs.

use mopeq::benchx::{bench, bench_items, section, BenchLog};
use mopeq::coordinator::{signround_optimize, SignRoundConfig};
use mopeq::jsonx::Json;
use mopeq::quant::{self, awq, gptq, kernels, pack};
use mopeq::rng::Rng;
use mopeq::runtime::Session;
use mopeq::tensor::Tensor;

fn main() {
    let mut log = BenchLog::new("quant_throughput");
    let mut rng = Rng::new(0);
    let w = Tensor::randn(&mut rng, &[64, 32], 0.5);
    let x = Tensor::randn(&mut rng, &[256, 64], 1.0);

    section("host quantizers (one expert FC 64x32)");
    let mut host = Vec::new();
    for bits in [2u8, 3, 4] {
        let s = bench(&format!("rtn_b{bits}"), || {
            quant::rtn_quantize(&w, bits, 32)
        });
        host.push((format!("rtn_b{bits}"), BenchLog::stats_json(&s)));
    }
    let s = bench("gptq_b4 (256 calib rows)", || {
        gptq::gptq_quantize(&w, &x, 4, 32, 0.01).unwrap()
    });
    host.push(("gptq_b4".into(), BenchLog::stats_json(&s)));
    let s = bench("awq_b4 (256 calib rows)", || {
        awq::awq_quantize(&w, &x, 4, 32, 0.5)
    });
    host.push(("awq_b4".into(), BenchLog::stats_json(&s)));
    log.put("host_quantizers", Json::Obj(host));

    section("bit packing (64x32 codes)");
    let qm = quant::rtn_quantize(&w, 4, 32);
    let mut packing = Vec::new();
    for bits in [2u8, 3, 4, 8] {
        let q = quant::rtn_quantize(&w, bits, 32);
        let s = bench_items(&format!("pack_b{bits}"), (64 * 32) as f64, || {
            pack::pack(&q.codes, 64, 32, bits).unwrap()
        });
        packing.push((format!("pack_b{bits}"), BenchLog::stats_json(&s)));
    }
    let packed = pack::pack(&qm.codes, 64, 32, 4).unwrap();
    let s = bench_items("unpack_b4", (64 * 32) as f64, || {
        pack::unpack(&packed, 64, 32, 4)
    });
    packing.push(("unpack_b4".into(), BenchLog::stats_json(&s)));
    bench("dequantize_b4", || qm.dequantize());
    log.put("packing", Json::Obj(packing));

    section("fused packed qmatmul vs f32 dense ([64,512] @ [512,512])");
    let (rows, din, dout) = (64usize, 512usize, 512usize);
    let wb = Tensor::randn(&mut rng, &[din, dout], 0.5);
    let xb = Tensor::randn(&mut rng, &[rows, din], 1.0);
    let gbs = |bytes: usize, secs: f64| bytes as f64 / secs / 1e9;
    let dense_bytes = din * dout * 4;
    let sd = bench("dense_f32_matmul", || {
        kernels::matmul_f32(&xb.data, rows, din, &wb.data, dout)
    });
    let dense_gbs = gbs(dense_bytes, sd.mean.as_secs_f64());
    println!(
        "{:<44} weight bytes/matmul {:>9}  read {:.2} GB/s",
        "", dense_bytes, dense_gbs
    );
    let mut dense_entry = match BenchLog::stats_json(&sd) {
        Json::Obj(o) => o,
        _ => unreachable!(),
    };
    dense_entry
        .push(("weight_bytes".into(), Json::Num(dense_bytes as f64)));
    dense_entry.push(("gbs".into(), Json::Num(dense_gbs)));
    log.put("dense", Json::Obj(dense_entry));
    let mut qmatmul_entries = Vec::new();
    for bits in [2u8, 3, 4, 8] {
        let qm = quant::rtn_quantize(&wb, bits, 32);
        let pm = kernels::PackedMatrix::from_quantized(&qm).unwrap();
        // parity guard: the fused kernel must be bit-exact vs the
        // dequantize-then-matmul golden path before we time it
        assert_eq!(
            kernels::qmatmul(&xb.data, rows, &pm),
            kernels::matmul_f32(
                &xb.data, rows, din, &qm.dequantize().data, dout
            ),
            "qmatmul{bits} diverged from the qdq->f32 path"
        );
        let st = bench(&format!("qmatmul{bits}_fused"), || {
            kernels::qmatmul(&xb.data, rows, &pm)
        });
        let kernel_gbs = gbs(pm.heap_bytes(), st.mean.as_secs_f64());
        println!(
            "{:<44} weight bytes/matmul {:>9}  read {:.2} GB/s \
             ({:.1}x fewer bytes than f32)",
            "",
            pm.heap_bytes(),
            kernel_gbs,
            dense_bytes as f64 / pm.heap_bytes() as f64
        );
        let mut entry = match BenchLog::stats_json(&st) {
            Json::Obj(o) => o,
            _ => unreachable!(),
        };
        entry.push((
            "weight_bytes".into(),
            Json::Num(pm.heap_bytes() as f64),
        ));
        entry.push(("gbs".into(), Json::Num(kernel_gbs)));
        qmatmul_entries.push((bits.to_string(), Json::Obj(entry)));
    }
    log.put("qmatmul", Json::Obj(qmatmul_entries));

    section("SignRound HLO step (Pallas qdq fwd + STE bwd + SignSGD)");
    match Session::open_default() {
        Ok(s) => {
            let xs = Tensor::randn(&mut rng, &[64, 64], 1.0);
            let cfg = SignRoundConfig { steps: 10, lr: 0.02, calib_rows: 64 };
            // warm the executable so the bench measures steps, not compile
            let _ = signround_optimize(&s, &w, &xs, 2, 32, &cfg);
            let mut sr = Vec::new();
            for bits in [2u8, 4] {
                let st = bench_items(
                    &format!("signround_10steps_b{bits}"),
                    10.0,
                    || signround_optimize(&s, &w, &xs, bits, 32, &cfg)
                        .unwrap(),
                );
                sr.push((
                    format!("b{bits}"),
                    BenchLog::stats_json(&st),
                ));
            }
            log.put("signround", Json::Obj(sr));
        }
        Err(e) => println!("(skipping HLO benches: {e})"),
    }

    match log.save() {
        Ok(path) => println!(
            "\nwrote {} (feed it back: `mopeq search --profile {}`)",
            path.display(),
            path.display()
        ),
        Err(e) => eprintln!("could not write BENCH json: {e}"),
    }
}
