//! Quantizer throughput: RTN / GPTQ / AWQ host paths, bit pack/unpack,
//! the fused packed qmatmul kernels vs the f32 dense baseline (weight
//! GB/s — the §5.4 bandwidth argument, measured), and the SignRound HLO
//! step — the cost side of the paper's method (PTQ cost per expert FC
//! layer).

use mopeq::benchx::{bench, bench_items, section};
use mopeq::coordinator::{signround_optimize, SignRoundConfig};
use mopeq::quant::{self, awq, gptq, kernels, pack};
use mopeq::rng::Rng;
use mopeq::runtime::Session;
use mopeq::tensor::Tensor;

fn main() {
    let mut rng = Rng::new(0);
    let w = Tensor::randn(&mut rng, &[64, 32], 0.5);
    let x = Tensor::randn(&mut rng, &[256, 64], 1.0);

    section("host quantizers (one expert FC 64x32)");
    for bits in [2u8, 3, 4] {
        bench(&format!("rtn_b{bits}"), || {
            quant::rtn_quantize(&w, bits, 32)
        });
    }
    bench("gptq_b4 (256 calib rows)", || {
        gptq::gptq_quantize(&w, &x, 4, 32, 0.01).unwrap()
    });
    bench("awq_b4 (256 calib rows)", || {
        awq::awq_quantize(&w, &x, 4, 32, 0.5)
    });

    section("bit packing (64x32 codes)");
    let qm = quant::rtn_quantize(&w, 4, 32);
    for bits in [2u8, 3, 4, 8] {
        let q = quant::rtn_quantize(&w, bits, 32);
        bench_items(&format!("pack_b{bits}"), (64 * 32) as f64, || {
            pack::pack(&q.codes, 64, 32, bits).unwrap()
        });
    }
    let packed = pack::pack(&qm.codes, 64, 32, 4).unwrap();
    bench_items("unpack_b4", (64 * 32) as f64, || {
        pack::unpack(&packed, 64, 32, 4)
    });
    bench("dequantize_b4", || qm.dequantize());

    section("fused packed qmatmul vs f32 dense ([64,512] @ [512,512])");
    let (rows, din, dout) = (64usize, 512usize, 512usize);
    let wb = Tensor::randn(&mut rng, &[din, dout], 0.5);
    let xb = Tensor::randn(&mut rng, &[rows, din], 1.0);
    let gbs = |bytes: usize, secs: f64| bytes as f64 / secs / 1e9;
    let dense_bytes = din * dout * 4;
    let sd = bench("dense_f32_matmul", || {
        kernels::matmul_f32(&xb.data, rows, din, &wb.data, dout)
    });
    println!(
        "{:<44} weight bytes/matmul {:>9}  read {:.2} GB/s",
        "",
        dense_bytes,
        gbs(dense_bytes, sd.mean.as_secs_f64())
    );
    for bits in [2u8, 3, 4, 8] {
        let qm = quant::rtn_quantize(&wb, bits, 32);
        let pm = kernels::PackedMatrix::from_quantized(&qm).unwrap();
        // parity guard: the fused kernel must be bit-exact vs the
        // dequantize-then-matmul golden path before we time it
        assert_eq!(
            kernels::qmatmul(&xb.data, rows, &pm),
            kernels::matmul_f32(
                &xb.data, rows, din, &qm.dequantize().data, dout
            ),
            "qmatmul{bits} diverged from the qdq->f32 path"
        );
        let st = bench(&format!("qmatmul{bits}_fused"), || {
            kernels::qmatmul(&xb.data, rows, &pm)
        });
        println!(
            "{:<44} weight bytes/matmul {:>9}  read {:.2} GB/s \
             ({:.1}x fewer bytes than f32)",
            "",
            pm.heap_bytes(),
            gbs(pm.heap_bytes(), st.mean.as_secs_f64()),
            dense_bytes as f64 / pm.heap_bytes() as f64
        );
    }

    section("SignRound HLO step (Pallas qdq fwd + STE bwd + SignSGD)");
    match Session::open_default() {
        Ok(s) => {
            let xs = Tensor::randn(&mut rng, &[64, 64], 1.0);
            let cfg = SignRoundConfig { steps: 10, lr: 0.02, calib_rows: 64 };
            // warm the executable so the bench measures steps, not compile
            let _ = signround_optimize(&s, &w, &xs, 2, 32, &cfg);
            for bits in [2u8, 4] {
                bench_items(
                    &format!("signround_10steps_b{bits}"),
                    10.0,
                    || signround_optimize(&s, &w, &xs, bits, 32, &cfg)
                        .unwrap(),
                );
            }
        }
        Err(e) => println!("(skipping HLO benches: {e})"),
    }
}
