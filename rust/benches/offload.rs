//! §5.4 offload-traffic bench: bytes moved per request under each
//! precision-assignment policy, swept over device-cache sizes — the
//! quantitative version of the paper's hardware-implications argument.
//! Skewed (MolmoE-like) routing; hot experts are the sensitive ones
//! under AF (high bits) but not under MoPEQ.

use mopeq::benchx::section;
use mopeq::cluster::{assign_map, Granularity};
use mopeq::config;
use mopeq::moe::PrecisionMap;
use mopeq::serve::{expert_bytes, simulate_offload, LinkModel, RoutingDist};

fn main() {
    let cfg = config::variant("molmoe").unwrap();
    let lm = cfg.moe_layers();

    // skewed routing: 8 hot experts per layer get 50x the traffic
    let mut weights = vec![vec![1.0f64; cfg.experts]; lm];
    for layer in weights.iter_mut() {
        for e in 0..8 {
            layer[e] = 50.0;
        }
    }
    let dist = RoutingDist::from_weights(&weights);

    // AF-style: importance == routing weight (hot => high bits).
    let af_map = PrecisionMap {
        bits: assign_map(&weights, &[2, 3, 4], Granularity::ModelWise, 0),
    };
    // MoPEQ-style: sensitivity decreasing with depth, independent of
    // hotness (the init design of the sim models).
    let sens: Vec<Vec<f64>> = (0..lm)
        .map(|l| vec![(lm - l) as f64; cfg.experts])
        .collect();
    let mopeq_map = PrecisionMap {
        bits: assign_map(&sens, &[2, 3, 4], Granularity::ModelWise, 0),
    };
    let uniform4 = PrecisionMap::uniform(&cfg, 4);
    let uniform3 = PrecisionMap::uniform(&cfg, 3);

    let full: usize = uniform4
        .iter_experts()
        .map(|(_, b)| expert_bytes(&cfg, b))
        .sum();
    let link = LinkModel::default();
    let requests = 400;

    section(&format!(
        "bytes/request vs cache size ({} requests, molmoe topology, \
         skewed routing)",
        requests
    ));
    println!(
        "{:<10} {:>14} {:>14} {:>14} {:>14}",
        "cache", "AF-map", "MoPEQ-map", "uniform4", "uniform3"
    );
    for frac in [0.05, 0.125, 0.25, 0.5, 1.0] {
        let cache = (full as f64 * frac) as usize;
        let mut row = format!("{:>8.1}% ", frac * 100.0);
        for m in [&af_map, &mopeq_map, &uniform4, &uniform3] {
            let r = simulate_offload(&cfg, m, &dist, &link, cache,
                                     requests, 7);
            row.push_str(&format!(" {:>13.0}", r.bytes_per_request));
        }
        println!("{row}");
    }

    section("hit rate + link time at 25% cache");
    let cache = full / 4;
    for (label, m) in [("AF-map", &af_map), ("MoPEQ-map", &mopeq_map),
                       ("uniform4", &uniform4)] {
        let r = simulate_offload(&cfg, m, &dist, &link, cache, requests, 7);
        println!(
            "{label:<10} hit-rate {:.3}  transfer {:.3} ms/request \
             ({} misses)",
            r.hit_rate,
            r.transfer_secs * 1e3 / requests as f64,
            r.misses
        );
    }
}
