//! §5.4 offload-traffic bench: bytes moved per request under each
//! precision-assignment policy, swept over device-cache sizes — the
//! quantitative version of the paper's hardware-implications argument.
//! Skewed (MolmoE-like) routing; hot experts are the sensitive ones
//! under AF (high bits) but not under MoPEQ.
//!
//! The second half is **measured**, not simulated: a 2-worker packed
//! engine on the tiered expert store, swept over `resident_bytes`
//! caps, with real rps/p99/hit-rate per cap. Emits
//! `reports/BENCH_offload.json` so the offload trajectory is diffable
//! across PRs.

use mopeq::benchx::{section, BenchLog};
use mopeq::cluster::{assign_map, Granularity};
use mopeq::config;
use mopeq::data::{gen_sample, Task};
use mopeq::engine::{Engine, MetricsSnapshot, PrecisionSource, WeightForm};
use mopeq::jsonx::Json;
use mopeq::moe::{local_meta, PrecisionMap, WeightStore};
use mopeq::rng::Rng;
use mopeq::serve::{expert_bytes, simulate_offload, LinkModel, RoutingDist};
use mopeq::store::StoreSnapshot;

fn drive(engine: Engine, n: usize) -> anyhow::Result<MetricsSnapshot> {
    let cfg = engine.config().clone();
    let client = engine.client();
    let mut rng = Rng::new(11).derive("offload-bench");
    let mut pending = Vec::with_capacity(n);
    for _ in 0..n {
        let task = Task::ALL[rng.below(Task::ALL.len())];
        pending.push(client.submit(gen_sample(task, &cfg, &mut rng))?);
    }
    for t in pending {
        t.wait()?;
    }
    engine.shutdown()
}

/// One measured sweep point as a BENCH_offload.json row.
fn cap_row(
    label: &str,
    cap_bytes: usize,
    s: &MetricsSnapshot,
    st: Option<&StoreSnapshot>,
) -> Json {
    Json::Obj(vec![
        ("label".into(), Json::Str(label.to_string())),
        ("cap_bytes".into(), Json::Num(cap_bytes as f64)),
        ("requests".into(), Json::Num(s.requests as f64)),
        ("rps".into(), Json::Num(s.throughput_rps)),
        ("p50_ns".into(), Json::Num(s.p50.as_nanos() as f64)),
        ("p99_ns".into(), Json::Num(s.p99.as_nanos() as f64)),
        (
            "hit_rate".into(),
            st.map_or(Json::Null, |st| Json::Num(st.hit_rate())),
        ),
        (
            "resident_bytes".into(),
            st.map_or(Json::Null, |st| Json::Num(st.resident_bytes as f64)),
        ),
        (
            "evictions".into(),
            st.map_or(Json::Null, |st| Json::Num(st.evictions as f64)),
        ),
        (
            "bytes_paged".into(),
            st.map_or(Json::Null, |st| Json::Num(st.bytes_paged as f64)),
        ),
    ])
}

fn measured_sweep(log: &mut BenchLog) -> anyhow::Result<()> {
    section(
        "measured tiered store (dsvl2_tiny, mixed {2,3,4} map, \
         2 workers): rps/p99 vs resident-byte cap",
    );
    let cfg = config::variant("dsvl2_tiny")?;
    let map = PrecisionMap {
        bits: (0..cfg.moe_layers())
            .map(|l| {
                (0..cfg.experts)
                    .map(|e| [2u8, 3, 4][(l + e) % 3])
                    .collect()
            })
            .collect(),
    };
    let n = 48;
    let build = |cap: Option<usize>| -> anyhow::Result<Engine> {
        let mut b = Engine::builder(cfg.name)
            .weights(WeightStore::init(&cfg, &local_meta(&cfg), 0))
            .weight_form(WeightForm::Packed)
            .precision(PrecisionSource::Map(map.clone()))
            .workers(2)
            .queue_depth(n);
        if let Some(cap) = cap {
            b = b.resident_bytes(cap);
        }
        b.build()
    };
    let mut rows: Vec<Json> = Vec::new();
    // fully-resident baseline — its measured expert heap is the 100% cap
    let base = drive(build(None)?, n)?;
    let full_heap = base.resident.expert_heap_bytes;
    println!(
        "resident    heap {:>8} B  p99 {:?}  {:>7.1} req/s",
        full_heap, base.p99, base.throughput_rps
    );
    rows.push(cap_row("resident", full_heap, &base, None));
    for frac in [0.25, 0.5, 1.0] {
        let cap = (full_heap as f64 * frac) as usize;
        let s = drive(build(Some(cap))?, n)?;
        let st = s.store.clone().expect("tiered snapshot carries store");
        println!(
            "cap {:>4.0}%   heap {:>8} B  p99 {:?}  {:>7.1} req/s  \
             hit rate {:.3}  {} evictions  {} B paged",
            frac * 100.0,
            st.resident_bytes,
            s.p99,
            s.throughput_rps,
            st.hit_rate(),
            st.evictions,
            st.bytes_paged
        );
        rows.push(cap_row(
            &format!("cap-{:.0}pct", frac * 100.0),
            cap,
            &s,
            Some(&st),
        ));
    }
    log.put_num("requests_per_row", n as f64);
    log.put_num("full_heap_bytes", full_heap as f64);
    log.put("measured_rows", Json::Arr(rows));
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let cfg = config::variant("molmoe").unwrap();
    let lm = cfg.moe_layers();

    // skewed routing: 8 hot experts per layer get 50x the traffic
    let mut weights = vec![vec![1.0f64; cfg.experts]; lm];
    for layer in weights.iter_mut() {
        for e in 0..8 {
            layer[e] = 50.0;
        }
    }
    let dist = RoutingDist::from_weights(&weights);

    // AF-style: importance == routing weight (hot => high bits).
    let af_map = PrecisionMap {
        bits: assign_map(&weights, &[2, 3, 4], Granularity::ModelWise, 0),
    };
    // MoPEQ-style: sensitivity decreasing with depth, independent of
    // hotness (the init design of the sim models).
    let sens: Vec<Vec<f64>> = (0..lm)
        .map(|l| vec![(lm - l) as f64; cfg.experts])
        .collect();
    let mopeq_map = PrecisionMap {
        bits: assign_map(&sens, &[2, 3, 4], Granularity::ModelWise, 0),
    };
    let uniform4 = PrecisionMap::uniform(&cfg, 4);
    let uniform3 = PrecisionMap::uniform(&cfg, 3);

    let full: usize = uniform4
        .iter_experts()
        .map(|(_, b)| expert_bytes(&cfg, b))
        .sum();
    let link = LinkModel::default();
    let requests = 400;

    section(&format!(
        "bytes/request vs cache size ({} requests, molmoe topology, \
         skewed routing)",
        requests
    ));
    println!(
        "{:<10} {:>14} {:>14} {:>14} {:>14}",
        "cache", "AF-map", "MoPEQ-map", "uniform4", "uniform3"
    );
    for frac in [0.05, 0.125, 0.25, 0.5, 1.0] {
        let cache = (full as f64 * frac) as usize;
        let mut row = format!("{:>8.1}% ", frac * 100.0);
        for m in [&af_map, &mopeq_map, &uniform4, &uniform3] {
            let r = simulate_offload(&cfg, m, &dist, &link, cache,
                                     requests, 7);
            row.push_str(&format!(" {:>13.0}", r.bytes_per_request));
        }
        println!("{row}");
    }

    section("hit rate + link time at 25% cache");
    let cache = full / 4;
    let mut sim_rows: Vec<Json> = Vec::new();
    for (label, m) in [("AF-map", &af_map), ("MoPEQ-map", &mopeq_map),
                       ("uniform4", &uniform4)] {
        let r = simulate_offload(&cfg, m, &dist, &link, cache, requests, 7);
        println!(
            "{label:<10} hit-rate {:.3}  transfer {:.3} ms/request \
             ({} misses)",
            r.hit_rate,
            r.transfer_secs * 1e3 / requests as f64,
            r.misses
        );
        sim_rows.push(Json::Obj(vec![
            ("label".into(), Json::Str(label.to_string())),
            ("hit_rate".into(), Json::Num(r.hit_rate)),
            (
                "bytes_per_request".into(),
                Json::Num(r.bytes_per_request),
            ),
            ("misses".into(), Json::Num(r.misses as f64)),
        ]));
    }

    let mut log = BenchLog::new("offload");
    log.put("simulated_rows_25pct_cache", Json::Arr(sim_rows));
    measured_sweep(&mut log)?;
    let path = log.save()?;
    println!("\nwrote {}", path.display());
    Ok(())
}
