//! Algorithm 2 cost: K-means clustering + precision assignment at both
//! granularities, across the four model topologies — negligible next to
//! quantization, which is the point (the paper's assignment step is
//! free).

use mopeq::benchx::{bench, section};
use mopeq::cluster::{assign_bits, assign_map, assign_percent_split,
                     Granularity};
use mopeq::config;
use mopeq::rng::Rng;

fn main() {
    let mut rng = Rng::new(0);

    section("1-D kmeans assignment (k=3 bits {2,3,4})");
    for n in [64usize, 768, 2160] {
        let vals: Vec<f64> = (0..n).map(|_| rng.uniform() * 10.0).collect();
        bench(&format!("assign_bits_n{n}"), || {
            assign_bits(&vals, &[2, 3, 4], 0)
        });
    }

    section("whole-model assignment per variant");
    for cfg in config::variants() {
        let map: Vec<Vec<f64>> = (0..cfg.moe_layers())
            .map(|_| (0..cfg.experts).map(|_| rng.uniform()).collect())
            .collect();
        for (tag, gran) in [("layer", Granularity::LayerWise),
                            ("model", Granularity::ModelWise)] {
            bench(&format!("{}_{tag}", cfg.name), || {
                assign_map(&map, &[2, 3, 4], gran, 0)
            });
        }
    }

    section("baseline percentage split (ablation comparator)");
    let vals: Vec<f64> = (0..768).map(|_| rng.uniform()).collect();
    bench("percent_split_n768", || {
        assign_percent_split(&vals, &[2, 3, 4])
    });
}
