//! Serving bench: end-to-end latency/throughput of the engine under
//! fp16 vs mixed-precision weights (qdq→f32 vs bit-packed execution,
//! with *measured* resident expert bytes), the **quantizer axis**
//! (RTN vs SignRound at 4-bit packed: build-time calibration cost vs
//! steady-state rps/p99), the **worker-count sweep** (the scale-out
//! axis: N executor replicas over Arc-shared weights), and the
//! batch-linger policy sweep (throughput vs tail latency), and a
//! **network row**: the same packed engine behind the HTTP front-end,
//! driven by the loopback load generator, so the wire overhead
//! (rps, client p50/p99) is diffable against the in-process rows.
//!
//! Emits `reports/BENCH_serving.json` (one row per configuration:
//! rps, p50/p99 ns, mean fill, resident expert bytes) so the serving
//! perf trajectory is diffable across PRs.

use mopeq::benchx::{section, BenchLog};
use mopeq::jsonx::Json;
use mopeq::cluster::Granularity;
use mopeq::config;
use mopeq::coordinator::{Quantizer, SignRoundConfig};
use mopeq::data::{gen_sample, Task};
use mopeq::engine::spec::{CalibSpec, QuantSpec};
use mopeq::engine::{Engine, MetricsSnapshot, PrecisionSource, WeightForm};
use mopeq::importance::hessian_closed_form;
use mopeq::moe::{local_meta, PrecisionMap, WeightStore};
use mopeq::net::{LoadSpec, NetConfig, NetServer};
use mopeq::rng::Rng;
use mopeq::serve::{expert_bytes, BatchPolicy};
use std::time::{Duration, Instant};

fn fresh_store(seed: u64) -> (config::ModelConfig, WeightStore) {
    let cfg = config::variant("dsvl2_tiny").unwrap();
    let ws = WeightStore::init(&cfg, &local_meta(&cfg), seed);
    (cfg, ws)
}

fn drive(engine: Engine, n: usize) -> anyhow::Result<MetricsSnapshot> {
    let cfg = engine.config().clone();
    let client = engine.client();
    let mut rng = Rng::new(9).derive("serving-bench");
    let mut pending = Vec::with_capacity(n);
    for _ in 0..n {
        let task = Task::ALL[rng.below(Task::ALL.len())];
        pending.push(client.submit(gen_sample(task, &cfg, &mut rng))?);
    }
    for t in pending {
        t.wait()?;
    }
    engine.shutdown()
}

/// One configuration's steady-state numbers as a BENCH_serving.json row.
fn snap_row(label: &str, workers: usize, s: &MetricsSnapshot) -> Json {
    Json::Obj(vec![
        ("label".into(), Json::Str(label.to_string())),
        ("workers".into(), Json::Num(workers as f64)),
        ("requests".into(), Json::Num(s.requests as f64)),
        ("batches".into(), Json::Num(s.batches as f64)),
        ("mean_fill".into(), Json::Num(s.mean_fill)),
        ("rps".into(), Json::Num(s.throughput_rps)),
        ("p50_ns".into(), Json::Num(s.p50.as_nanos() as f64)),
        ("p99_ns".into(), Json::Num(s.p99.as_nanos() as f64)),
        (
            "resident_expert_bytes".into(),
            Json::Num(s.resident.expert_accounted_bytes as f64),
        ),
    ])
}

fn mopeq_map(cfg: &config::ModelConfig, ws: &WeightStore) -> PrecisionMap {
    let sens = hessian_closed_form(ws, cfg).unwrap();
    PrecisionMap {
        bits: mopeq::cluster::assign_map(
            &sens.values,
            &[2, 3, 4],
            Granularity::ModelWise,
            0,
        ),
    }
}

fn main() -> anyhow::Result<()> {
    let full = std::env::var_os("MOPEQ_FULL").is_some();
    let n = if full { 256 } else { 64 };
    let mut log = BenchLog::new("serving");
    let mut rows_json: Vec<Json> = Vec::new();

    section("precision maps (batch linger 2ms, 1 worker)");
    let (cfg, ws) = fresh_store(0);
    let mixed = mopeq_map(&cfg, &ws);
    let rows: [(&str, WeightForm, PrecisionSource); 4] = [
        ("fp16", WeightForm::Fp16, PrecisionSource::Reference),
        (
            "uniform4-rtn",
            WeightForm::DequantizedF32,
            PrecisionSource::Uniform(4),
        ),
        (
            "mopeq-mixed-rtn",
            WeightForm::DequantizedF32,
            PrecisionSource::Map(mixed.clone()),
        ),
        (
            "mopeq-mixed-packed",
            WeightForm::Packed,
            PrecisionSource::Map(mixed.clone()),
        ),
    ];
    for (label, form, precision) in rows {
        let (_, w) = fresh_store(0);
        let engine = Engine::builder(cfg.name)
            .weights(w)
            .weight_form(form)
            .precision(precision)
            // the bench pre-submits the whole workload before waiting,
            // so the admission bound must cover it (MOPEQ_FULL: n=256)
            .queue_depth(n)
            .build()?;
        let s = drive(engine, n)?;
        println!(
            "{label:<18} {:>4} reqs  fill {:.2}  p50 {:?}  p95 {:?}  \
             {:>7.1} req/s  experts resident {:>8} B ({} f32 tensors)",
            s.requests,
            s.mean_fill,
            s.p50,
            s.p95,
            s.throughput_rps,
            s.resident.expert_accounted_bytes,
            s.resident.dense_expert_tensors,
        );
        rows_json.push(snap_row(label, 1, &s));
    }
    let accounted: usize = mixed
        .iter_experts()
        .map(|(_, b)| expert_bytes(&cfg, b))
        .sum();
    println!(
        "(SizePolicy accounting for the mixed map: {accounted} B — the \
         packed row's resident bytes must equal it)"
    );

    section(
        "quantizer axis (4-bit packed, 1 worker): build-time \
         calibration cost vs steady state",
    );
    let quantizer_rows: [(&str, QuantSpec); 2] = [
        ("rtn", QuantSpec::rtn()),
        (
            "signround",
            QuantSpec::calibrated(
                Quantizer::SignRound(SignRoundConfig {
                    steps: 12,
                    ..SignRoundConfig::default()
                }),
                CalibSpec { batches: 2, rows: 64 },
            ),
        ),
    ];
    for (label, quant) in quantizer_rows {
        let (_, w) = fresh_store(0);
        let t0 = Instant::now();
        let engine = Engine::builder(cfg.name)
            .weights(w)
            .weight_form(WeightForm::Packed)
            .precision(PrecisionSource::Uniform(4))
            .quantizer(quant)
            .queue_depth(n)
            .build()?;
        let build = t0.elapsed();
        let s = drive(engine, n)?;
        println!(
            "{label:<10} build {build:>8.2?} (capture+quantize+pack)  \
             {:>4} reqs  p50 {:?}  p99 {:?}  {:>7.1} req/s",
            s.requests, s.p50, s.p99, s.throughput_rps
        );
        let mut row = snap_row(&format!("quantizer-{label}"), 1, &s);
        if let Json::Obj(fields) = &mut row {
            fields.push((
                "build_ns".into(),
                Json::Num(build.as_nanos() as f64),
            ));
        }
        rows_json.push(row);
    }
    println!(
        "(same packed execution path once built — the quantizers \
         differ in build cost and accuracy, not serving speed)"
    );

    section("worker-count sweep (scale-out: rps and p99 vs replicas)");
    for (label, form, precision) in [
        ("fp16-dense", WeightForm::Fp16, PrecisionSource::Reference),
        (
            "mopeq-packed",
            WeightForm::Packed,
            PrecisionSource::Map(mixed.clone()),
        ),
    ] {
        for workers in [1usize, 2, 4] {
            let (_, w) = fresh_store(0);
            let engine = Engine::builder(cfg.name)
                .weights(w)
                .weight_form(form)
                .precision(precision.clone())
                .workers(workers)
                .queue_depth(n)
                .build()?;
            let s = drive(engine, n)?;
            println!(
                "{label:<14} workers {workers}  {:>4} reqs  fill {:.2}  \
                 p99 {:?}  {:>7.1} req/s",
                s.requests, s.mean_fill, s.p99, s.throughput_rps
            );
            rows_json.push(snap_row(label, workers, &s));
        }
    }

    section("batch linger sweep (fp16, 1 worker)");
    for linger_ms in [0u64, 2, 8] {
        let (_, w) = fresh_store(0);
        let engine = Engine::builder(cfg.name)
            .weights(w)
            .batch_policy(BatchPolicy {
                max_linger: Duration::from_millis(linger_ms),
            })
            .queue_depth(n)
            .build()?;
        let s = drive(engine, n)?;
        println!(
            "linger {linger_ms:>2} ms  batches {:>4}  fill {:.2}  \
             p50 {:?}  p95 {:?}  {:>7.1} req/s",
            s.batches, s.mean_fill, s.p50, s.p95, s.throughput_rps
        );
        rows_json.push(snap_row(&format!("linger-{linger_ms}ms"), 1, &s));
    }

    section(
        "network front-end (loopback HTTP, packed engine): wire \
         overhead on top of the in-process rows",
    );
    {
        let (_, w) = fresh_store(0);
        let engine = Engine::builder(cfg.name)
            .weights(w)
            .weight_form(WeightForm::Packed)
            .precision(PrecisionSource::Map(mixed.clone()))
            .workers(2)
            .queue_depth(n)
            .build()?;
        let server = NetServer::spawn(engine, NetConfig::default())?;
        let addr = server.local_addr().to_string();
        let spec = LoadSpec {
            addr,
            concurrency: 4,
            duration: Duration::from_secs_f64(if full { 6.0 } else { 2.0 }),
            ..LoadSpec::default()
        };
        let load = mopeq::net::loadgen::run(&spec)?;
        let s = server.shutdown()?;
        println!(
            "net-loopback-packed  {:>4} ok (correct {})  busy {}  \
             wire p50 {:?}  p99 {:?}  {:>7.1} req/s",
            load.ok, load.correct, load.busy, load.p50, load.p99, load.rps
        );
        // same shape as snap_row, but the latencies are the client's
        // round-trip times: the delta vs the in-process packed rows IS
        // the wire overhead
        rows_json.push(Json::Obj(vec![
            ("label".into(), Json::Str("net-loopback-packed".into())),
            ("workers".into(), Json::Num(2.0)),
            ("requests".into(), Json::Num(load.ok as f64)),
            ("batches".into(), Json::Num(s.batches as f64)),
            ("mean_fill".into(), Json::Num(s.mean_fill)),
            ("rps".into(), Json::Num(load.rps)),
            ("p50_ns".into(), Json::Num(load.p50.as_nanos() as f64)),
            ("p99_ns".into(), Json::Num(load.p99.as_nanos() as f64)),
            (
                "resident_expert_bytes".into(),
                Json::Num(s.resident.expert_accounted_bytes as f64),
            ),
        ]));
    }

    log.put_num("requests_per_row", n as f64);
    log.put("rows", Json::Arr(rows_json));
    let path = log.save()?;
    println!("\nwrote {}", path.display());
    Ok(())
}
