//! Serving bench: end-to-end latency/throughput of the threaded batching
//! server under fp16 vs mixed-precision weights (qdq→f32 vs bit-packed
//! execution, with *measured* resident expert bytes), and the
//! batch-linger policy sweep (throughput vs tail latency).

use mopeq::benchx::section;
use mopeq::cluster::Granularity;
use mopeq::config;
use mopeq::coordinator::{quantize_experts, Quantizer};
use mopeq::data::{gen_sample, Task};
use mopeq::importance::hessian_closed_form;
use mopeq::moe::{local_meta, PackedStore, PrecisionMap, WeightStore};
use mopeq::rng::Rng;
use mopeq::serve::{expert_bytes, BatchPolicy, ServerHandle};
use std::time::Duration;

fn fresh_store(seed: u64) -> (config::ModelConfig, WeightStore) {
    let cfg = config::variant("dsvl2_tiny").unwrap();
    let ws = WeightStore::init(&cfg, &local_meta(&cfg), seed);
    (cfg, ws)
}

fn drive(handle: ServerHandle, cfg: &config::ModelConfig, n: usize)
         -> anyhow::Result<mopeq::serve::ServerStats> {
    let mut rng = Rng::new(9).derive("serving-bench");
    let mut pending = Vec::with_capacity(n);
    for _ in 0..n {
        let task = Task::ALL[rng.below(Task::ALL.len())];
        pending.push(handle.submit(gen_sample(task, cfg, &mut rng))?);
    }
    for rx in pending {
        rx.recv()?;
    }
    handle.shutdown()
}

fn run(cfg: &config::ModelConfig, ws: WeightStore, policy: BatchPolicy,
       n: usize) -> anyhow::Result<mopeq::serve::ServerStats> {
    drive(ServerHandle::start(cfg.clone(), ws, policy)?, cfg, n)
}

fn main() -> anyhow::Result<()> {
    let n = if std::env::var_os("MOPEQ_FULL").is_some() { 256 } else { 64 };

    section("precision maps (batch linger 2ms)");
    let (cfg, ws) = fresh_store(0);
    let sens = hessian_closed_form(&ws, &cfg)?;
    let mopeq_map = PrecisionMap {
        bits: mopeq::cluster::assign_map(
            &sens.values, &[2, 3, 4], Granularity::ModelWise, 0),
    };
    for label in ["fp16", "uniform4-rtn", "mopeq-mixed-rtn",
                  "mopeq-mixed-packed"] {
        let (_, mut w) = fresh_store(0);
        let s = match label {
            "uniform4-rtn" => {
                quantize_experts(None, &cfg, &mut w,
                                 &PrecisionMap::uniform(&cfg, 4),
                                 &Quantizer::Rtn, None)?;
                run(&cfg, w, BatchPolicy::default(), n)?
            }
            "mopeq-mixed-rtn" => {
                quantize_experts(None, &cfg, &mut w, &mopeq_map,
                                 &Quantizer::Rtn, None)?;
                run(&cfg, w, BatchPolicy::default(), n)?
            }
            "mopeq-mixed-packed" => {
                // same codes as the rtn row, served bit-packed
                let store = PackedStore::rtn(&cfg, &w, &mopeq_map)?;
                drive(
                    ServerHandle::start_packed(
                        cfg.clone(), w, store, BatchPolicy::default())?,
                    &cfg, n,
                )?
            }
            _ => run(&cfg, w, BatchPolicy::default(), n)?,
        };
        println!(
            "{label:<18} {:>4} reqs  fill {:.2}  p50 {:?}  p95 {:?}  \
             {:>7.1} req/s  experts resident {:>8} B ({} f32 tensors)",
            s.requests, s.mean_fill, s.p50, s.p95, s.throughput_rps,
            s.resident.expert_accounted_bytes,
            s.resident.dense_expert_tensors,
        );
    }
    let accounted: usize = mopeq_map
        .iter_experts()
        .map(|(_, b)| expert_bytes(&cfg, b))
        .sum();
    println!(
        "(SizePolicy accounting for the mixed map: {accounted} B — the \
         packed row's resident bytes must equal it)"
    );

    section("batch linger sweep (fp16)");
    for linger_ms in [0u64, 2, 8] {
        let (_, w) = fresh_store(0);
        let s = run(
            &cfg,
            w,
            BatchPolicy { max_linger: Duration::from_millis(linger_ms) },
            n,
        )?;
        println!(
            "linger {linger_ms:>2} ms  batches {:>4}  fill {:.2}  \
             p50 {:?}  p95 {:?}  {:>7.1} req/s",
            s.batches, s.mean_fill, s.p50, s.p95, s.throughput_rps
        );
    }
    Ok(())
}
