//! API stub of the `xla` crate (PJRT bindings), covering exactly the
//! surface `mopeq::runtime::xla` calls. Every constructor returns a
//! runtime error, so `cargo build --features backend-xla` always
//! compiles and the binary degrades to a clear "stub build" message if
//! the XLA backend is requested.
//!
//! To run the real PJRT path, replace this path dependency in
//! `rust/Cargo.toml` with the actual `xla` crate (same module surface);
//! no `mopeq` source changes are required.

use std::borrow::Borrow;
use std::fmt;
use std::marker::PhantomData;
use std::path::Path;

/// Stub error: always "not linked".
pub struct Error(String);

impl Error {
    fn stub(what: &str) -> Error {
        Error(format!(
            "xla stub: `{what}` requires the real PJRT bindings — \
             replace rust/vendor/xla with the actual xla crate \
             (see DESIGN.md §Backends)"
        ))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

pub type Result<T> = std::result::Result<T, Error>;

/// Element types we exchange with PJRT.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
}

/// Marker for host element types the literal API accepts.
pub trait NativeType: Copy {
    const TY: ElementType;
}

impl NativeType for f32 {
    const TY: ElementType = ElementType::F32;
}

impl NativeType for i32 {
    const TY: ElementType = ElementType::S32;
}

/// Host literal (stub: shape/type metadata only, no storage).
pub struct Literal {
    _p: PhantomData<()>,
}

pub struct ArrayShape {
    _p: PhantomData<()>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &[]
    }

    pub fn ty(&self) -> ElementType {
        ElementType::F32
    }
}

impl Literal {
    pub fn vec1<T: NativeType>(_data: &[T]) -> Literal {
        Literal { _p: PhantomData }
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(Error::stub("Literal::reshape"))
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        Err(Error::stub("Literal::array_shape"))
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Err(Error::stub("Literal::to_vec"))
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(Error::stub("Literal::to_tuple"))
    }
}

/// Device buffer handle (stub).
pub struct PjRtBuffer {
    _p: PhantomData<()>,
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::stub("PjRtBuffer::to_literal_sync"))
    }
}

/// Compiled executable handle (stub).
pub struct PjRtLoadedExecutable {
    _p: PhantomData<()>,
}

impl PjRtLoadedExecutable {
    pub fn execute_b<B: Borrow<PjRtBuffer>>(
        &self,
        _args: &[B],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::stub("PjRtLoadedExecutable::execute_b"))
    }
}

/// PJRT client (stub: `cpu()` fails, so callers bail at session open).
pub struct PjRtClient {
    _p: PhantomData<()>,
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::stub("PjRtClient::cpu"))
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::stub("PjRtClient::compile"))
    }

    pub fn buffer_from_host_literal(
        &self,
        _device: Option<usize>,
        _lit: &Literal,
    ) -> Result<PjRtBuffer> {
        Err(Error::stub("PjRtClient::buffer_from_host_literal"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }
}

/// Parsed HLO module (stub).
pub struct HloModuleProto {
    _p: PhantomData<()>,
}

impl HloModuleProto {
    pub fn from_text_file(_path: impl AsRef<Path>) -> Result<HloModuleProto> {
        Err(Error::stub("HloModuleProto::from_text_file"))
    }
}

/// XLA computation wrapper (stub).
pub struct XlaComputation {
    _p: PhantomData<()>,
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _p: PhantomData }
    }
}
