//! Vendored, dependency-free subset of the `anyhow` crate API, so the
//! workspace builds hermetically from a clean checkout with no network
//! access and no pre-populated registry cache.
//!
//! Covered surface (exactly what this repository uses):
//! - [`Error`]: message + source chain, `Debug`/`Display` (`{:#}` prints
//!   the full chain like real anyhow)
//! - [`Result<T>`] alias
//! - `anyhow!`, `bail!`, `ensure!` macros (format-style)
//! - [`Context::context`] / [`Context::with_context`] on `Result<T, Error>`
//! - `From<E: std::error::Error + Send + Sync + 'static>` so `?` converts
//!   std errors (io, utf8, …) into [`Error`]
//! - [`Error::downcast_ref`]: typed errors converted through `From` keep
//!   their payload (anywhere in the chain), so callers can match on
//!   structured error enums like real anyhow
//!
//! To switch back to the real crate, replace the path dependency in
//! `rust/Cargo.toml` with a registry version — no call sites change.

use std::fmt;

/// Error type: an outermost message plus an optional chain of causes,
/// carrying the original typed value when built from one.
pub struct Error {
    msg: String,
    source: Option<Box<Error>>,
    payload: Option<Box<dyn std::any::Any + Send + Sync>>,
}

impl Error {
    /// Construct from anything displayable (what `anyhow!` expands to).
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { msg: message.to_string(), source: None, payload: None }
    }

    /// Construct from a typed error, preserving it for
    /// [`downcast_ref`](Error::downcast_ref) (same as `.into()`).
    pub fn new<E: std::error::Error + Send + Sync + 'static>(e: E) -> Error {
        e.into()
    }

    /// Wrap `self` under a new outermost context message.
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        Error {
            msg: context.to_string(),
            source: Some(Box::new(self)),
            payload: None,
        }
    }

    /// The typed error this chain was built from, if any level of it
    /// was converted from a `T` (mirrors real anyhow's chain search).
    pub fn downcast_ref<T: 'static>(&self) -> Option<&T> {
        let mut cur = Some(self);
        while let Some(e) = cur {
            if let Some(t) =
                e.payload.as_ref().and_then(|p| p.downcast_ref::<T>())
            {
                return Some(t);
            }
            cur = e.source.as_deref();
        }
        None
    }

    /// The innermost error in the chain.
    pub fn root_cause(&self) -> &Error {
        let mut cur = self;
        while let Some(src) = &cur.source {
            cur = src;
        }
        cur
    }

    /// Iterate the chain outermost-first.
    pub fn chain(&self) -> Chain<'_> {
        Chain { next: Some(self) }
    }
}

/// Iterator over an error chain (outermost context first).
pub struct Chain<'a> {
    next: Option<&'a Error>,
}

impl<'a> Iterator for Chain<'a> {
    type Item = &'a Error;

    fn next(&mut self) -> Option<&'a Error> {
        let cur = self.next?;
        self.next = cur.source.as_deref();
        Some(cur)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` — full chain, colon-separated, like real anyhow
            write!(f, "{}", self.msg)?;
            let mut cur = &self.source;
            while let Some(src) = cur {
                write!(f, ": {}", src.msg)?;
                cur = &src.source;
            }
            Ok(())
        } else {
            write!(f, "{}", self.msg)
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        if let Some(src) = &self.source {
            write!(f, "\n\nCaused by:")?;
            let mut cur = Some(src);
            while let Some(e) = cur {
                write!(f, "\n    {}", e.msg)?;
                cur = e.source.as_deref();
            }
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        // preserve the std source chain as message context
        let mut msgs = Vec::new();
        msgs.push(e.to_string());
        let mut src = e.source();
        while let Some(s) = src {
            msgs.push(s.to_string());
            src = s.source();
        }
        let mut err: Option<Error> = None;
        for m in msgs.into_iter().rev() {
            err = Some(Error { msg: m, source: err.map(Box::new), payload: None });
        }
        let mut err = err.expect("non-empty chain");
        // keep the typed value for downcast_ref
        err.payload = Some(Box::new(e));
        err
    }
}

/// `anyhow::Result<T>` — the crate-wide result alias.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to an error (subset: implemented for `Result<T, Error>`,
/// which is the only receiver this repository uses).
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T> Context<T> for Result<T, Error> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.context(f()))
    }
}

/// Construct an [`Error`] from a format string (or any displayable).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(format!("{}", $err))
    };
}

/// Early-return with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($tt:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($tt)*))
    };
}

/// Early-return with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::Error::msg(format!(
                "condition failed: {}",
                stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($tt:tt)*) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($tt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        bail!("inner {}", 42)
    }

    #[test]
    fn display_and_chain() {
        let e = fails().unwrap_err().context("outer");
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: inner 42");
        assert_eq!(e.root_cause().to_string(), "inner 42");
        assert_eq!(e.chain().count(), 2);
    }

    #[test]
    fn from_std_error() {
        let io = std::io::Error::new(std::io::ErrorKind::Other, "boom");
        let e: Error = io.into();
        assert_eq!(e.to_string(), "boom");
    }

    #[test]
    fn downcast_preserves_typed_payload_through_context() {
        #[derive(Debug, PartialEq)]
        struct MyErr(u32);
        impl fmt::Display for MyErr {
            fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
                write!(f, "my error {}", self.0)
            }
        }
        impl std::error::Error for MyErr {}

        let e: Error = MyErr(7).into();
        assert_eq!(e.downcast_ref::<MyErr>(), Some(&MyErr(7)));
        // context wrapping keeps the payload reachable down the chain
        let wrapped = e.context("outer");
        assert_eq!(wrapped.downcast_ref::<MyErr>(), Some(&MyErr(7)));
        assert!(wrapped.downcast_ref::<std::io::Error>().is_none());
        // plain message errors carry no payload
        assert!(Error::msg("plain").downcast_ref::<MyErr>().is_none());
    }

    #[test]
    fn with_context_on_result() {
        let r: Result<()> = fails().with_context(|| format!("ctx {}", 1));
        assert_eq!(format!("{:#}", r.unwrap_err()), "ctx 1: inner 42");
    }

    #[test]
    fn ensure_passes_and_fails() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x > 0, "x must be positive, got {x}");
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert!(f(-1).is_err());
    }
}
